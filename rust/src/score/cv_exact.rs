//! The exact cross-validated generalized score (Huang et al. 2018), the
//! paper's Eq. (8) (conditional) and Eq. (9) (marginal) — `O(n³)` time,
//! `O(n²)` memory. This is the baseline "CV" that CV-LR approximates,
//! and the ground truth the approximation is validated against (Table 1).
//!
//! Centering convention: train features are centered by the train mean;
//! test features are mapped with the *same* train mean (the regression
//! model is fit in the train feature space). All cross/test kernel blocks
//! below use that convention; CV-LR uses the identical convention on the
//! low-rank factors, so the two scores agree to factorization error.

use std::sync::Arc;

use super::folds::{stride_folds, CvParams};
use super::LocalScore;
use crate::data::Dataset;
use crate::kernel::{gram, median_heuristic, Kernel};
use crate::linalg::{Cholesky, Mat};

/// Exact CV score over a dataset.
pub struct CvExactScore {
    pub ds: Arc<Dataset>,
    pub params: CvParams,
}

impl CvExactScore {
    pub fn new(ds: Arc<Dataset>, params: CvParams) -> Self {
        CvExactScore { ds, params }
    }

    /// RBF kernel for a variable block with the paper's width rule.
    fn kernel_for(&self, block: &Mat) -> Kernel {
        Kernel::Rbf { sigma: median_heuristic(block, self.params.width_factor) }
    }
}

/// Kernel blocks of one CV fold, centered by the train mean.
pub(crate) struct FoldBlocks {
    /// K̃¹ (train × train, doubly centered).
    pub k11: Mat,
    /// K̃^{0,1} (test × train, train-mean centered).
    pub k01: Mat,
    /// Tr(K̃⁰) — the only part of the test×test block the score needs.
    pub tr_k00: f64,
}

/// Extract and center the fold blocks of a full kernel matrix.
pub(crate) fn fold_blocks(k: &Mat, test: &[usize], train: &[usize]) -> FoldBlocks {
    let n1 = train.len();
    let n0 = test.len();
    // train col means and grand mean
    let mut colmean = vec![0.0; n1];
    let mut grand = 0.0;
    for (a, &i) in train.iter().enumerate() {
        let mut s = 0.0;
        for &j in train {
            s += k[(i, j)];
        }
        colmean[a] = s / n1 as f64;
        grand += s;
    }
    let grand = grand / (n1 as f64 * n1 as f64);

    let mut k11 = Mat::zeros(n1, n1);
    for (a, &i) in train.iter().enumerate() {
        for (b, &j) in train.iter().enumerate() {
            k11[(a, b)] = k[(i, j)] - colmean[a] - colmean[b] + grand;
        }
    }

    let mut k01 = Mat::zeros(n0, n1);
    let mut tr_k00 = 0.0;
    for (a, &i) in test.iter().enumerate() {
        let mut rowmean = 0.0;
        for &j in train {
            rowmean += k[(i, j)];
        }
        rowmean /= n1 as f64;
        for (b, &j) in train.iter().enumerate() {
            k01[(a, b)] = k[(i, j)] - rowmean - colmean[b] + grand;
        }
        tr_k00 += k[(i, i)] - 2.0 * rowmean + grand;
    }
    FoldBlocks { k11, k01, tr_k00 }
}

/// Eq. (8): one fold of the conditional score from centered blocks.
pub(crate) fn fold_score_cond(x: &FoldBlocks, z: &FoldBlocks, p: &CvParams) -> f64 {
    let n1 = x.k11.rows as f64;
    let n0 = x.k01.rows as f64;
    let (lam, gam, beta) = (p.lambda, p.gamma, p.beta());

    // A = (K̃_Z¹ + n₁λI)⁻¹
    let a = Cholesky::new(&z.k11.add_diag(n1 * lam))
        .expect("K̃_Z + n1λI must be SPD")
        .inverse();
    // B = A K̃_X¹ A
    let ax = a.matmul(&x.k11);
    let b = ax.matmul(&a);
    // log|n₁βB + I|
    let q = b.scale(n1 * beta).add_diag(1.0);
    let chq = Cholesky::new(&q).expect("I + n1βB must be SPD");
    let logdet = chq.log_det();
    // C = A (I + n₁βB)⁻¹ A
    let inner = chq.inverse();
    let c = a.matmul(&inner).matmul(&a);

    // Trace terms of Eq. (8).
    let t1 = x.tr_k00;
    let zb = z.k01.matmul(&b);
    let t2 = zb.frob_dot(&z.k01); // Tr(K̃z01 B K̃z10)
    let xa = x.k01.matmul(&a);
    let t3 = xa.frob_dot(&z.k01); // Tr(K̃x01 A K̃z10)
    let xc = x.k01.matmul(&c);
    let t4 = xc.frob_dot(&x.k01); // Tr(K̃x01 C K̃x10)
    let zax = z.k01.matmul(&a).matmul(&x.k11); // K̃z01 A K̃x¹
    let t5 = zax.matmul(&c).frob_dot(&zax); // Tr(K̃z01 A K̃x¹ C K̃x¹ A K̃z10)
    let t6 = xc.matmul(&x.k11).matmul(&a).frob_dot(&z.k01); // Tr(K̃x01 C K̃x¹ A K̃z10)

    let trace_total =
        t1 + t2 - 2.0 * t3 - n1 * beta * t4 - n1 * beta * t5 + 2.0 * n1 * beta * t6;

    -(n0 * n0 / 2.0) * (2.0 * std::f64::consts::PI).ln()
        - (n0 / 2.0) * logdet
        - (n0 * n1 / 2.0) * gam.ln()
        - trace_total / (2.0 * gam)
}

/// Eq. (9): one fold of the marginal (|Z| = 0) score.
pub(crate) fn fold_score_marg(x: &FoldBlocks, p: &CvParams) -> f64 {
    let n1 = x.k11.rows as f64;
    let n0 = x.k01.rows as f64;
    let (lam, gam) = (p.lambda, p.gamma);

    // B̌ = (I + K̃_X¹/(n₁λ))⁻¹ and log|I + K̃_X¹/(n₁λ)|  (§5 "|z|=0" form).
    let q = x.k11.scale(1.0 / (n1 * lam)).add_diag(1.0);
    let chq = Cholesky::new(&q).expect("I + K̃x/(n1λ) must be SPD");
    let logdet = chq.log_det();
    let bchk = chq.inverse();

    let xb = x.k01.matmul(&bchk);
    let t2 = xb.frob_dot(&x.k01); // Tr(K̃x01 B̌ K̃x10)
    let trace_total = x.tr_k00 - t2 / (n1 * gam);

    -(n0 * n0 / 2.0) * (2.0 * std::f64::consts::PI).ln()
        - (n0 / 2.0) * logdet
        - (n0 * n1 / 2.0) * gam.ln()
        - trace_total / (2.0 * gam)
}

impl LocalScore for CvExactScore {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        let xblock = self.ds.block(target);
        let kx_fun = self.kernel_for(&xblock);
        let kx = gram(kx_fun, &xblock);
        let folds = stride_folds(self.ds.n(), self.params.folds);

        if parents.is_empty() {
            let mut total = 0.0;
            for (test, train) in &folds {
                let fx = fold_blocks(&kx, test, train);
                total += fold_score_marg(&fx, &self.params);
            }
            return total / folds.len() as f64;
        }

        let zblock = self.ds.block_multi(parents);
        let kz_fun = self.kernel_for(&zblock);
        let kz = gram(kz_fun, &zblock);
        let mut total = 0.0;
        for (test, train) in &folds {
            let fx = fold_blocks(&kx, test, train);
            let fz = fold_blocks(&kz, test, train);
            total += fold_score_cond(&fx, &fz, &self.params);
        }
        total / folds.len() as f64
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn make_ds(n: usize, seed: u64) -> Arc<Dataset> {
        // X2 = tanh(X1) + noise; X3 independent.
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = x1.tanh() + 0.3 * rng.normal();
            let x3 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
        }
        Arc::new(Dataset::from_columns(data, &[false, false, false]))
    }

    #[test]
    fn fold_blocks_match_feature_space_centering() {
        // verify K̃01 against explicit feature-space computation for the
        // linear kernel (features = raw values).
        let x = Mat::from_vec(6, 1, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let k = gram(Kernel::Linear, &x);
        let test = vec![0, 3];
        let train = vec![1, 2, 4, 5];
        let fb = fold_blocks(&k, &test, &train);
        let train_mean = (2.0 + 3.0 + 5.0 + 6.0) / 4.0;
        for (a, &i) in test.iter().enumerate() {
            for (b, &j) in train.iter().enumerate() {
                let expect = (x[(i, 0)] - train_mean) * (x[(j, 0)] - train_mean);
                assert!((fb.k01[(a, b)] - expect).abs() < 1e-12);
            }
        }
        let tr_expect: f64 = test.iter().map(|&i| (x[(i, 0)] - train_mean).powi(2)).sum();
        assert!((fb.tr_k00 - tr_expect).abs() < 1e-12);
    }

    #[test]
    fn dependent_parent_scores_higher_than_independent() {
        let ds = make_ds(120, 1);
        let s = CvExactScore::new(ds, CvParams::default());
        let with_true_parent = s.local_score(1, &[0]);
        let with_wrong_parent = s.local_score(1, &[2]);
        let marginal = s.local_score(1, &[]);
        assert!(
            with_true_parent > marginal,
            "true parent must beat marginal: {with_true_parent} vs {marginal}"
        );
        assert!(
            with_true_parent > with_wrong_parent,
            "true parent must beat wrong parent: {with_true_parent} vs {with_wrong_parent}"
        );
    }

    #[test]
    fn independent_variable_prefers_empty_parents() {
        let ds = make_ds(120, 2);
        let s = CvExactScore::new(ds, CvParams::default());
        let marginal = s.local_score(2, &[]);
        let spurious = s.local_score(2, &[0]);
        // X3 ⊥ X1 — adding the parent must not improve the score much;
        // local consistency says marginal wins asymptotically.
        assert!(
            marginal > spurious - 1.0,
            "marginal {marginal} should not lose badly to spurious {spurious}"
        );
    }

    #[test]
    fn score_is_deterministic() {
        let ds = make_ds(60, 3);
        let s = CvExactScore::new(ds, CvParams::default());
        assert_eq!(s.local_score(0, &[1]), s.local_score(0, &[1]));
    }
}
