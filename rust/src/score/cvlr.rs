//! CV-LR — the paper's contribution: the cross-validated generalized
//! score computed from low-rank kernel factors in **O(n m²)** time and
//! **O(n m)** space (paper §5).
//!
//! Every n×n object of Eq. (8) is rewritten through the dumbbell-form
//! rules (Woodbury / multiplicative closure / trace cycling /
//! Weinstein–Aronszajn) into products of the m×m cores
//!
//! ```text
//!   P = Λ̃ₓ₁ᵀΛ̃ₓ₁   E = Λ̃_z₁ᵀΛ̃ₓ₁   F = Λ̃_z₁ᵀΛ̃_z₁      (train)
//!   V = Λ̃ₓ₀ᵀΛ̃ₓ₀   U = Λ̃_z₀ᵀΛ̃ₓ₀   S = Λ̃_z₀ᵀΛ̃_z₀      (test)
//! ```
//!
//! with `D = (n₁λI + F)⁻¹`, `T = P − 2EᵀDE + EᵀDFDE`,
//! `Q = I + T/(n₁γ)` (whose Cholesky gives both `log|n₁βB+I| = log|Q|`
//! and the `Q⁻¹·` solves), and `W = Λ̃ₓ₁ᵀCΛ̃ₓ₁ = c₁²T − n₁β c₁⁴ T Q⁻¹ T`
//! (`c₁ = 1/(n₁λ)`) — algebraically identical to the paper's
//! 𝔄/𝔅/ℭ/𝔇 decomposition (Eq. 18-19) but with fewer products. `D` and
//! `Q⁻¹` are never formed: every appearance is a triangular solve
//! against the corresponding Cholesky factor. The final trace is
//! Eq. (26): `Tr[(I − n₁βW)·M₂]` with
//! `M₂ = V − 2c₁·Eᵀ(I−DF)U + c₁²·Eᵀ(I−DF)S(I−DF)ᵀE`.
//!
//! **Core-provider architecture** (see [`super::cores`]): the per-fold
//! centered cores are *not* recomputed from n×m factors per candidate.
//! A [`FoldCoreCache`] holds, per variable set, the downdated self-core
//! bundle ([`SetCores`]: one O(n·m²) pass, P/V per fold by `G_train =
//! G_full − G_test` + rank-one mean corrections), shared by every
//! candidate, segment and GES sweep; per unique (parents → target) pair
//! a segment computes the cross-cores ([`PairCores`], the only
//! remaining O(n·mz·mx) per-pair work) once. The [`CvLrKernel`] backends
//! consume the assembled [`CondCores`]/[`MargCores`] views — natively
//! (this module) or through the AOT-compiled XLA artifacts
//! (`runtime::PjrtKernel`), which synthesize m-row surrogate factors
//! from the cores so the device never sees the n×m factors at all.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::cores::{cond_fold, FoldCoreCache, PairCoreCache, PairCores, SetCores};
pub use super::cores::{CondCores, CondCoresBuf, MargCores, MargCoresBuf};
use super::folds::{stride_folds, CvParams};
use super::{LocalScore, ScoreBackend, ScoreRequest};
use crate::data::Dataset;
use crate::kernel::{median_heuristic, Kernel};
use crate::linalg::{Cholesky, Mat};
use crate::lowrank::{factorize, LowRank, LowRankConfig};

/// Backend for the per-fold CV-LR score evaluation, consuming
/// precomputed centered cores (the provider output of
/// [`super::cores`]).
pub trait CvLrKernel: Send + Sync {
    /// Conditional score (Eq. 8 via §5): one fold, from cores.
    fn score_cond_cores(&self, c: &CondCores<'_>, p: &CvParams) -> f64;
    /// Marginal score (Eq. 9 via §5 "|z|=0"): one fold, from cores.
    fn score_marg_cores(&self, c: &MargCores<'_>, p: &CvParams) -> f64;

    /// All folds of one conditional score in a single submission.
    /// Backends that pay a per-invocation dispatch cost (PJRT) override
    /// this to amortize it; the default evaluates fold by fold, so the
    /// batched and scalar paths are bit-identical by construction.
    fn score_cond_batch(&self, folds: &[CondCores<'_>], p: &CvParams) -> Vec<f64> {
        folds.iter().map(|c| self.score_cond_cores(c, p)).collect()
    }

    /// All folds of one marginal score in a single submission.
    fn score_marg_batch(&self, folds: &[MargCores<'_>], p: &CvParams) -> Vec<f64> {
        folds.iter().map(|c| self.score_marg_cores(c, p)).collect()
    }

    /// Straight-line factor entry point (the pre-downdating reference,
    /// kept for tests and cross-engine validation): factors already
    /// centered by the train mean → direct `t_matmul` cores → the core
    /// algebra.
    fn score_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> f64 {
        let buf = CondCoresBuf::from_centered_factors(lx0, lx1, lz0, lz1);
        self.score_cond_cores(&buf.view(), p)
    }

    /// Factor entry point of the marginal score (see
    /// [`CvLrKernel::score_cond`]).
    fn score_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> f64 {
        let buf = MargCoresBuf::from_centered_factors(lx0, lx1);
        self.score_marg_cores(&buf.view(), p)
    }

    /// Human-readable backend name (for bench output).
    fn name(&self) -> &'static str;
}

/// Pure-rust f64 implementation of the dumbbell-form algebra.
pub struct NativeCvLrKernel;

impl CvLrKernel for NativeCvLrKernel {
    fn score_cond_cores(&self, c: &CondCores<'_>, p: &CvParams) -> f64 {
        let n1 = c.n1 as f64;
        let n0 = c.n0 as f64;
        let (lam, gam, beta) = (p.lambda, p.gamma, p.beta());
        let c1 = 1.0 / (n1 * lam);

        // D = (n₁λ I + F)⁻¹ enters only through D·E and D·F: two
        // triangular solves against one Cholesky factorization — no
        // m³ inverse is ever formed.
        let chd = Cholesky::new(&c.f.add_diag(n1 * lam)).expect("F + n1λI SPD");
        let de = chd.solve(c.e); // D·E (mz×mx)
        let df = chd.solve(c.f); // D·F (mz×mz)
        // T = P − 2 EᵀDE + EᵀDFDE = (n₁λ)² Λ̃ᵀA²Λ̃   (Eq. 17)
        let et_de = c.e.t_matmul(&de); // EᵀDE (mx×mx)
        let fde = c.f.matmul(&de);
        let et_dfde = de.t_matmul(&fde); // EᵀDFDE
        let t = &(c.p - &et_de.scale(2.0)) + &et_dfde;

        // Q = I + T/(n₁γ); log|Q| = log|n₁βB + I| (Eq. 20-21); Q⁻¹T by
        // solve against the same factorization.
        let q = t.scale(1.0 / (n1 * gam)).add_diag(1.0);
        let chq = Cholesky::new(&q).expect("Q SPD");
        let logdet = chq.log_det();

        // W = c₁²·T − n₁β·c₁⁴·T(Q⁻¹T)  (mx×mx)
        let qt = chq.solve(&t);
        let tgt = t.matmul(&qt);
        let w = &t.scale(c1 * c1) - &tgt.scale(n1 * beta * c1.powi(4));

        // I − DF (mz×mz) and M₂ (Eq. 26).
        let idf = &Mat::eye(c.f.rows) - &df;
        let et_idf = c.e.t_matmul(&idf); // Eᵀ(I−DF)  (mx×mz)
        let m2 = {
            let second = et_idf.matmul(c.u); // Eᵀ(I−DF)U (mx×mx)
            let third = et_idf.matmul(c.s).matmul_t(&et_idf); // Eᵀ(I−DF)S(I−DF)ᵀE
            &(c.v - &second.scale(2.0 * c1)) + &third.scale(c1 * c1)
        };

        // Tr[(I − n₁βW) M₂]
        let total_trace = m2.trace() - n1 * beta * w.trace_prod(&m2);

        -(n0 * n0 / 2.0) * (2.0 * std::f64::consts::PI).ln()
            - (n0 / 2.0) * logdet
            - (n0 * n1 / 2.0) * gam.ln()
            - total_trace / (2.0 * gam)
    }

    fn score_marg_cores(&self, c: &MargCores<'_>, p: &CvParams) -> f64 {
        let n1 = c.n1 as f64;
        let n0 = c.n0 as f64;
        let (lam, gam) = (p.lambda, p.gamma);
        let c1 = 1.0 / (n1 * lam);

        // Q̌ = I + c₁ P; log|Q̌| = log|I + c₁K̃ₓ¹| (Eq. 28); Ď·P by solve.
        let q = c.p.scale(c1).add_diag(1.0);
        let chq = Cholesky::new(&q).expect("Q̌ SPD");
        let logdet = chq.log_det();

        // Tr(K̃⁰) = Tr(V); Tr(K̃⁰¹B̌K̃¹⁰) = Tr(VP) − c₁Tr((VP)(ĎP))  (Eq. 29-30)
        let vp = c.v.matmul(c.p);
        let tr_vp = vp.trace();
        let dp = chq.solve(c.p);
        let tr_vpdp = vp.trace_prod(&dp);
        let trace_total = c.v.trace() - (tr_vp - c1 * tr_vpdp) / (n1 * gam);

        -(n0 * n0 / 2.0) * (2.0 * std::f64::consts::PI).ln()
            - (n0 / 2.0) * logdet
            - (n0 * n1 / 2.0) * gam.ln()
            - trace_total / (2.0 * gam)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Split a full-data factor into (test, train) fold factors, both
/// centered by the *train* column means (matching `cv_exact`). No
/// longer on the hot path — the provider ([`super::cores`]) derives the
/// same cores by downdating — but kept as the straight-line reference
/// the property tests compare against.
pub fn split_center(lam: &Mat, test: &[usize], train: &[usize]) -> (Mat, Mat) {
    let m = lam.cols;
    let mut mean = vec![0.0; m];
    for &r in train {
        for c in 0..m {
            mean[c] += lam[(r, c)];
        }
    }
    for mc in &mut mean {
        *mc /= train.len() as f64;
    }
    let take = |rows: &[usize]| {
        let mut out = Mat::zeros(rows.len(), m);
        for (i, &r) in rows.iter().enumerate() {
            for c in 0..m {
                out[(i, c)] = lam[(r, c)] - mean[c];
            }
        }
        out
    };
    (take(test), take(train))
}

/// The CV-LR local score with per-variable-set factor *and* fold-core
/// caching.
pub struct CvLrScore<K: CvLrKernel> {
    pub ds: Arc<Dataset>,
    pub params: CvParams,
    pub lr_cfg: LowRankConfig,
    pub backend: K,
    /// Gram-product threads (`DiscoveryConfig::parallelism`).
    parallelism: usize,
    /// Low-rank factors keyed by the sorted variable set.
    factor_cache: Mutex<HashMap<Vec<usize>, Arc<Mat>>>,
    /// Downdated per-(set, fold) self-cores, built once per set for the
    /// life of the score and shared by every candidate and sweep.
    fold_cores: FoldCoreCache,
    /// Centered E/U cross-cores per (target, parents) pair, shared
    /// across batch segments and sweeps — the repeated-candidate twin
    /// of the self-core cache.
    pair_cores: PairCoreCache,
}

impl CvLrScore<NativeCvLrKernel> {
    /// CV-LR with the native rust backend and paper-default parameters.
    pub fn native(ds: Arc<Dataset>) -> Self {
        CvLrScore::with_backend(ds, CvParams::default(), LowRankConfig::default(), NativeCvLrKernel)
    }
}

impl<K: CvLrKernel> CvLrScore<K> {
    pub fn with_backend(ds: Arc<Dataset>, params: CvParams, lr_cfg: LowRankConfig, backend: K) -> Self {
        CvLrScore {
            ds,
            params,
            lr_cfg,
            backend,
            parallelism: 1,
            factor_cache: Mutex::new(HashMap::new()),
            fold_cores: FoldCoreCache::new(),
            pair_cores: PairCoreCache::new(),
        }
    }

    /// Gram-product threads for the fold-core builds (default 1; `0` =
    /// auto — available cores capped at the fold count; see
    /// `score::cores` for the partitioning contract).
    pub fn with_parallelism(mut self, threads: usize) -> Self {
        self.parallelism = super::cores::resolve_parallelism(threads, self.params.folds);
        self
    }

    /// The resolved Gram-product thread count (`0` inputs already
    /// resolved to the auto value).
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Bound the fold-core and pair-core caches to at most `capacity`
    /// entries each (second-chance eviction, mirroring
    /// `ScoreCache::with_capacity`). Unbounded by default; long-lived
    /// servers default this from their score-cache capacity.
    pub fn with_core_capacity(mut self, capacity: Option<usize>) -> Self {
        self.fold_cores = FoldCoreCache::with_capacity(capacity);
        self.pair_cores = PairCoreCache::with_capacity(capacity);
        self
    }

    /// Low-rank factor of the kernel matrix of a variable set (Algorithm
    /// 2 for small-cardinality discrete sets, Algorithm 1 otherwise).
    pub fn factor_for(&self, vars: &[usize]) -> Arc<Mat> {
        let mut key: Vec<usize> = vars.to_vec();
        key.sort_unstable();
        if let Some(f) = self.factor_cache.lock().unwrap().get(&key) {
            return f.clone();
        }
        let block = self.ds.block_multi(&key);
        let kern = Kernel::Rbf { sigma: median_heuristic(&block, self.params.width_factor) };
        let LowRank { lambda, .. } =
            factorize(kern, &block, self.ds.all_discrete(&key), &self.lr_cfg);
        let arc = Arc::new(lambda);
        self.factor_cache.lock().unwrap().insert(key, arc.clone());
        arc
    }

    /// Cached downdated self-cores of a variable set (built from the
    /// cached factor on first use).
    pub fn cores_for(&self, vars: &[usize]) -> Arc<SetCores> {
        let mut key: Vec<usize> = vars.to_vec();
        key.sort_unstable();
        if let Some(c) = self.fold_cores.get(&key) {
            return c;
        }
        let folds = stride_folds(self.ds.n(), self.params.folds);
        self.fold_cores.get_or_build(&key, &folds, self.parallelism, &mut || {
            self.factor_for(&key)
        })
    }
}

/// Score one batch segment given an external self-core source — the
/// machinery shared by [`CvLrScore`] (whose cores come from its
/// per-variable-set [`FoldCoreCache`]) and the streaming backend
/// (`stream::StreamBackend`, whose cores are rebuilt over incrementally
/// maintained `FactorState`s after every append). Per unique variable
/// set the provider hands back the cached downdated P/V bundle; per
/// unique (parents → target) pair the E/U cross-cores — the only
/// per-pair O(n·mz·mx) work — come from the caller's [`PairCoreCache`],
/// so a pair re-scored in a later segment or sweep pays nothing; every
/// candidate's fold scores are assembled from O(m²) core views.
/// Per-request values are independent of how the caller segments its
/// batches.
pub fn score_segment_with<K: CvLrKernel + ?Sized>(
    params: &CvParams,
    backend: &K,
    reqs: &[ScoreRequest],
    cores_for: &mut dyn FnMut(&[usize]) -> Arc<SetCores>,
    pairs: &PairCoreCache,
    parallelism: usize,
) -> Vec<f64> {
    let _span = crate::obs::trace::span("score-segment", "score")
        .arg("requests", reqs.len().to_string());
    // Unique variable sets referenced by the batch: every target
    // singleton plus every non-empty parent set.
    let mut sets: Vec<Vec<usize>> = Vec::with_capacity(2 * reqs.len());
    for r in reqs {
        sets.push(vec![r.target]);
        if !r.parents.is_empty() {
            sets.push(r.parents.clone());
        }
    }
    sets.sort_unstable();
    sets.dedup();

    // Self-cores per set, shared by all candidates below (and across
    // segments/sweeps through the caller's cache).
    let mut self_cores: HashMap<Vec<usize>, Arc<SetCores>> = HashMap::with_capacity(sets.len());
    for set in sets {
        let cores = cores_for(&set);
        self_cores.insert(set, cores);
    }

    // Cross-cores per unique (parents → target) pair in the segment,
    // resolved through the cross-segment pair cache.
    let mut cross: HashMap<(usize, Vec<usize>), Arc<PairCores>> = HashMap::new();
    for r in reqs {
        if r.parents.is_empty() {
            continue;
        }
        let key = (r.target, r.parents.clone());
        if cross.contains_key(&key) {
            continue;
        }
        let z = &self_cores[&r.parents[..]];
        let x = &self_cores[&[r.target][..]];
        let pc = pairs.get_or_build(r.target, &r.parents, z, x, parallelism);
        cross.insert(key, pc);
    }

    reqs.iter()
        .map(|r| {
            let x = &self_cores[&[r.target][..]];
            let nf = x.num_folds();
            let per_fold = if r.parents.is_empty() {
                let folds: Vec<MargCores<'_>> = (0..nf).map(|f| x.marg_fold(f)).collect();
                backend.score_marg_batch(&folds, params)
            } else {
                let z = &self_cores[&r.parents[..]];
                let pc = &cross[&(r.target, r.parents.clone())];
                let folds: Vec<CondCores<'_>> =
                    (0..nf).map(|f| cond_fold(x, z, pc, f)).collect();
                backend.score_cond_batch(&folds, params)
            };
            per_fold.iter().sum::<f64>() / nf as f64
        })
        .collect()
}

impl<K: CvLrKernel> CvLrScore<K> {
    /// One batch segment with fully shared per-set work (see
    /// `ScoreBackend::score_batch` below for the segmenting wrapper).
    fn score_segment(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        score_segment_with(
            &self.params,
            &self.backend,
            reqs,
            &mut |set: &[usize]| self.cores_for(set),
            &self.pair_cores,
            self.parallelism,
        )
    }
}

impl<K: CvLrKernel> ScoreBackend for CvLrScore<K> {
    /// Batch-aware evaluation: the expensive per-variable-set work —
    /// low-rank factorization and the downdated fold-core build — is
    /// done **once per unique set** (cached for the life of the score,
    /// not just a segment) and shared across every candidate that
    /// references it. A GES sweep scoring hundreds of parent-set
    /// variations of the same target pays for the target's P/V cores
    /// exactly once; the per-candidate cost collapses to one E/U
    /// cross-core pass plus the m×m core algebra, submitted to the fold
    /// kernel as one [`CvLrKernel::score_cond_batch`] call per
    /// candidate.
    ///
    /// Sweep-sized batches are processed in fixed segments so the
    /// transient cross-core storage stays bounded no matter how wide
    /// the search batches get; per-request values are independent of
    /// the segmentation, so results stay bit-identical.
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        const SEGMENT: usize = 64;
        if reqs.len() <= SEGMENT {
            return self.score_segment(reqs);
        }
        reqs.chunks(SEGMENT).flat_map(|seg| self.score_segment(seg)).collect()
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }

    fn core_cache_stats(&self) -> Option<(u64, u64)> {
        // resident entries / evictions across both core caches
        Some((
            self.fold_cores.len() as u64 + self.pair_cores.len() as u64,
            self.fold_cores.evictions() + self.pair_cores.evictions(),
        ))
    }

    /// Resident bytes across the fold-core and pair-core caches plus
    /// the factor cache's Λ matrices (keys included).
    fn core_cache_bytes(&self) -> Option<u64> {
        let factors: u64 = self
            .factor_cache
            .lock()
            .unwrap()
            .iter()
            .map(|(k, lam)| {
                lam.resident_bytes() + (k.capacity() * std::mem::size_of::<usize>()) as u64
            })
            .sum();
        Some(self.fold_cores.resident_bytes() + self.pair_cores.resident_bytes() + factors)
    }
}

impl<K: CvLrKernel> LocalScore for CvLrScore<K> {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        // A one-request batch: keeps the scalar and batched paths on
        // the same code, so they are bit-identical by construction.
        self.score_batch(&[ScoreRequest::new(target, parents)])[0]
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::cv_exact::CvExactScore;
    use crate::util::Pcg64;

    fn continuous_ds(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = (x1 + 0.2 * rng.normal()).sin() + 0.2 * rng.normal();
            let x3 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
        }
        Arc::new(Dataset::from_columns(data, &[false, false, false]))
    }

    fn discrete_ds(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 2);
        for r in 0..n {
            let a = rng.below(3);
            let b = if rng.bernoulli(0.8) { a } else { rng.below(3) };
            data[(r, 0)] = a as f64;
            data[(r, 1)] = b as f64;
        }
        Arc::new(Dataset::from_columns(data, &[true, true]))
    }

    /// The Table-1 anchor: CV-LR must match exact CV to < 0.5% relative
    /// error on continuous data with m = 100.
    #[test]
    fn matches_exact_cv_continuous() {
        let ds = continuous_ds(150, 1);
        let exact = CvExactScore::new(ds.clone(), CvParams::default());
        let lr = CvLrScore::native(ds);
        for (target, parents) in [(1usize, vec![0usize]), (0, vec![]), (1, vec![0, 2])] {
            let se = exact.local_score(target, &parents);
            let sl = lr.local_score(target, &parents);
            let rel = ((se - sl) / se).abs();
            assert!(rel < 5e-3, "target {target} parents {parents:?}: exact {se} lr {sl} rel {rel}");
        }
    }

    /// Discrete data: Algorithm 2 is exact (Lemma 4.3) so CV-LR must
    /// match exact CV to numerical precision — through the downdated
    /// core path.
    #[test]
    fn matches_exact_cv_discrete_exactly() {
        let ds = discrete_ds(100, 2);
        let exact = CvExactScore::new(ds.clone(), CvParams::default());
        let lr = CvLrScore::native(ds);
        for (target, parents) in [(1usize, vec![0usize]), (0, vec![]), (1, vec![])] {
            let se = exact.local_score(target, &parents);
            let sl = lr.local_score(target, &parents);
            let rel = ((se - sl) / se).abs();
            assert!(rel < 1e-9, "target {target} parents {parents:?}: exact {se} lr {sl} rel {rel}");
        }
    }

    #[test]
    fn local_consistency_direction() {
        let ds = continuous_ds(200, 3);
        let lr = CvLrScore::native(ds);
        let dep = lr.local_score(1, &[0]);
        let marg = lr.local_score(1, &[]);
        assert!(dep > marg, "dependent parent must improve the score: {dep} vs {marg}");
        let ind_marg = lr.local_score(2, &[]);
        let ind_spur = lr.local_score(2, &[0]);
        assert!(ind_marg > ind_spur - 1.0, "spurious parent should not win big");
    }

    #[test]
    fn factor_cache_reused() {
        let ds = continuous_ds(80, 4);
        let lr = CvLrScore::native(ds);
        let f1 = lr.factor_for(&[0, 1]);
        let f2 = lr.factor_for(&[1, 0]); // different order, same set
        assert!(Arc::ptr_eq(&f1, &f2));
        let c1 = lr.cores_for(&[0, 1]);
        let c2 = lr.cores_for(&[1, 0]);
        assert!(Arc::ptr_eq(&c1, &c2), "fold cores share the sorted-set key");
    }

    /// The E/U cross-cores of a (parents → target) pair persist across
    /// batch segments: a pair re-scored later hits the pair cache
    /// instead of repaying the O(n·mz·mx) cross-product pass.
    #[test]
    fn pair_cores_cached_across_segments() {
        let ds = continuous_ds(80, 10);
        let lr = CvLrScore::native(ds);
        let a = lr.local_score(1, &[0]);
        assert_eq!(lr.pair_cores.len(), 1, "one conditional pair resident");
        let b = lr.local_score(1, &[0]); // a fresh batch = a fresh segment
        assert_eq!(a, b, "cached cross-cores are the same bits");
        assert_eq!(lr.pair_cores.len(), 1, "repeat pair reused the cache");
        let _ = lr.local_score(2, &[0, 1]);
        assert_eq!(lr.pair_cores.len(), 2, "new pairs still insert");
        // marginals never touch the pair cache
        let _ = lr.local_score(0, &[]);
        assert_eq!(lr.pair_cores.len(), 2);
    }

    /// The downdated core path and the straight-line split_center
    /// reference must agree on full local scores.
    #[test]
    fn provider_path_matches_reference_scores() {
        let ds = continuous_ds(90, 7);
        let lr = CvLrScore::native(ds.clone());
        let got = lr.local_score(1, &[0, 2]);
        // reference: split_center factors, factor-level kernel entry
        let lx = lr.factor_for(&[1]);
        let lz = lr.factor_for(&[0, 2]);
        let folds = stride_folds(ds.n(), lr.params.folds);
        let k = NativeCvLrKernel;
        let want = folds
            .iter()
            .map(|(test, train)| {
                let (lx0, lx1) = split_center(&lx, test, train);
                let (lz0, lz1) = split_center(&lz, test, train);
                k.score_cond(&lx0, &lx1, &lz0, &lz1, &lr.params)
            })
            .sum::<f64>()
            / folds.len() as f64;
        let rel = ((got - want) / want).abs();
        assert!(rel < 1e-9, "provider {got} vs reference {want} (rel {rel})");
    }

    #[test]
    fn parallelism_matches_serial_scores() {
        let ds = continuous_ds(120, 9);
        let serial = CvLrScore::native(ds.clone());
        let par = CvLrScore::native(ds).with_parallelism(4);
        for (t, pa) in [(1usize, vec![0usize]), (0, vec![]), (2, vec![0, 1])] {
            let a = serial.local_score(t, &pa);
            let b = par.local_score(t, &pa);
            // parallelism ≤ Q keeps the summation grouping, so the
            // scores are bit-identical (see score::cores)
            assert_eq!(a, b, "target {t} parents {pa:?}");
        }
    }

    #[test]
    fn split_center_zero_means_on_train() {
        let mut rng = Pcg64::new(5);
        let lam = Mat::from_vec(20, 3, (0..60).map(|_| rng.normal()).collect());
        let test: Vec<usize> = (0..5).collect();
        let train: Vec<usize> = (5..20).collect();
        let (l0, l1) = split_center(&lam, &test, &train);
        assert_eq!(l0.rows, 5);
        assert_eq!(l1.rows, 15);
        for c in 0..3 {
            let s: f64 = (0..15).map(|r| l1[(r, c)]).sum();
            assert!(s.abs() < 1e-10, "train column {c} mean must be 0");
        }
    }

    /// Zero-column padding must not change the score — the invariance the
    /// fixed-shape XLA artifacts rely on (DESIGN.md §2).
    #[test]
    fn padding_invariance_native() {
        let ds = continuous_ds(100, 6);
        let lr = CvLrScore::native(ds);
        let lx = lr.factor_for(&[1]);
        let lz = lr.factor_for(&[0]);
        let folds = stride_folds(100, 10);
        let (test, train) = &folds[0];
        let (lx0, lx1) = split_center(&lx, test, train);
        let (lz0, lz1) = split_center(&lz, test, train);
        let k = NativeCvLrKernel;
        let s_ref = k.score_cond(&lx0, &lx1, &lz0, &lz1, &CvParams::default());
        let pad = |m: &Mat| m.pad_to(m.rows, m.cols + 7);
        let s_pad = k.score_cond(&pad(&lx0), &pad(&lx1), &pad(&lz0), &pad(&lz1), &CvParams::default());
        assert!(
            ((s_ref - s_pad) / s_ref).abs() < 1e-10,
            "column padding changed the score: {s_ref} vs {s_pad}"
        );
        let m_ref = k.score_marg(&lx0, &lx1, &CvParams::default());
        let m_pad = k.score_marg(&pad(&lx0), &pad(&lx1), &CvParams::default());
        assert!(((m_ref - m_pad) / m_ref).abs() < 1e-10);
    }
}
