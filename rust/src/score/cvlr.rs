//! CV-LR — the paper's contribution: the cross-validated generalized
//! score computed from low-rank kernel factors in **O(n m²)** time and
//! **O(n m)** space (paper §5).
//!
//! Every n×n object of Eq. (8) is rewritten through the dumbbell-form
//! rules (Woodbury / multiplicative closure / trace cycling /
//! Weinstein–Aronszajn) into products of the m×m cores
//!
//! ```text
//!   P = Λ̃ₓ₁ᵀΛ̃ₓ₁   E = Λ̃_z₁ᵀΛ̃ₓ₁   F = Λ̃_z₁ᵀΛ̃_z₁      (train)
//!   V = Λ̃ₓ₀ᵀΛ̃ₓ₀   U = Λ̃_z₀ᵀΛ̃ₓ₀   S = Λ̃_z₀ᵀΛ̃_z₀      (test)
//! ```
//!
//! with `D = (n₁λI + F)⁻¹`, `T = P − 2EᵀDE + EᵀDFDE`,
//! `Q = I + T/(n₁γ)` (whose Cholesky gives both `log|n₁βB+I| = log|Q|`
//! and `G = Q⁻¹`), and `W = Λ̃ₓ₁ᵀCΛ̃ₓ₁ = c₁²T − n₁β c₁⁴ T G T`
//! (`c₁ = 1/(n₁λ)`) — algebraically identical to the paper's
//! 𝔄/𝔅/ℭ/𝔇 decomposition (Eq. 18-19) but with fewer products.
//! The final trace is Eq. (26): `Tr[(I − n₁βW)·M₂]` with
//! `M₂ = V − 2c₁·Eᵀ(I−DF)U + c₁²·Eᵀ(I−DF)S(I−DF)ᵀE`.
//!
//! The m×m core algebra sits behind [`CvLrKernel`] so that it can run
//! either natively (this module) or through the AOT-compiled XLA
//! artifacts (`runtime::PjrtKernel`), which also compute the O(nm²)
//! Gram products with the L1 Pallas kernel.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::folds::{stride_folds, CvParams};
use super::{LocalScore, ScoreBackend, ScoreRequest};
use crate::data::Dataset;
use crate::kernel::{median_heuristic, Kernel};
use crate::linalg::{Cholesky, Mat};
use crate::lowrank::{factorize, LowRank, LowRankConfig};

/// One centered CV fold of conditional-score factors (borrowed views
/// into the per-batch split cache).
pub struct CondFold<'a> {
    pub lx0: &'a Mat,
    pub lx1: &'a Mat,
    pub lz0: &'a Mat,
    pub lz1: &'a Mat,
}

/// One centered CV fold of marginal-score factors.
pub struct MargFold<'a> {
    pub lx0: &'a Mat,
    pub lx1: &'a Mat,
}

/// Backend for the per-fold CV-LR score evaluation. Factors arrive
/// *already centered by the train mean*.
pub trait CvLrKernel: Send + Sync {
    /// Conditional score (Eq. 8 via §5): one fold.
    fn score_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> f64;
    /// Marginal score (Eq. 9 via §5 "|z|=0"): one fold.
    fn score_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> f64;

    /// All folds of one conditional score in a single submission.
    /// Backends that pay a per-invocation dispatch cost (PJRT) override
    /// this to amortize it; the default evaluates fold by fold, so the
    /// batched and scalar paths are bit-identical by construction.
    fn score_cond_batch(&self, folds: &[CondFold<'_>], p: &CvParams) -> Vec<f64> {
        folds.iter().map(|f| self.score_cond(f.lx0, f.lx1, f.lz0, f.lz1, p)).collect()
    }

    /// All folds of one marginal score in a single submission.
    fn score_marg_batch(&self, folds: &[MargFold<'_>], p: &CvParams) -> Vec<f64> {
        folds.iter().map(|f| self.score_marg(f.lx0, f.lx1, p)).collect()
    }

    /// Human-readable backend name (for bench output).
    fn name(&self) -> &'static str;
}

/// Pure-rust f64 implementation of the dumbbell-form algebra.
pub struct NativeCvLrKernel;

impl CvLrKernel for NativeCvLrKernel {
    fn score_cond(&self, lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat, p: &CvParams) -> f64 {
        let n1 = lx1.rows as f64;
        let n0 = lx0.rows as f64;
        let (lam, gam, beta) = (p.lambda, p.gamma, p.beta());
        let c1 = 1.0 / (n1 * lam);

        // m×m cores — the only O(n·m²) work.
        let pm = lx1.t_matmul(lx1); // P
        let e = lz1.t_matmul(lx1); // E
        let f = lz1.t_matmul(lz1); // F
        let v = lx0.t_matmul(lx0); // V
        let u = lz0.t_matmul(lx0); // U
        let s = lz0.t_matmul(lz0); // S

        // D = (n₁λ I + F)⁻¹  (mz×mz)
        let d = Cholesky::new(&f.add_diag(n1 * lam)).expect("F + n1λI SPD").inverse();
        // T = P − 2 EᵀDE + EᵀDFDE = (n₁λ)² Λ̃ᵀA²Λ̃   (Eq. 17)
        let de = d.matmul(&e); // mz×mx
        let et_de = e.t_matmul(&de); // EᵀDE (mx×mx)
        let fde = f.matmul(&de);
        let et_dfde = de.t_matmul(&fde); // EᵀDFDE
        let t = &(&pm - &et_de.scale(2.0)) + &et_dfde;

        // Q = I + T/(n₁γ); log|Q| = log|n₁βB + I| (Eq. 20-21); G = Q⁻¹.
        let q = t.scale(1.0 / (n1 * gam)).add_diag(1.0);
        let chq = Cholesky::new(&q).expect("Q SPD");
        let logdet = chq.log_det();
        let g = chq.inverse();

        // W = c₁²·T − n₁β·c₁⁴·T G T  (mx×mx)
        let tgt = t.matmul(&g).matmul(&t);
        let w = &t.scale(c1 * c1) - &tgt.scale(n1 * beta * c1.powi(4));

        // I − DF (mz×mz) and M₂ (Eq. 26).
        let idf = &Mat::eye(f.rows) - &d.matmul(&f);
        let et_idf = e.t_matmul(&idf); // Eᵀ(I−DF)  (mx×mz)
        let m2 = {
            let second = et_idf.matmul(&u); // Eᵀ(I−DF)U (mx×mx)
            let third = et_idf.matmul(&s).matmul_t(&et_idf); // Eᵀ(I−DF)S(I−DF)ᵀE
            &(&v - &second.scale(2.0 * c1)) + &third.scale(c1 * c1)
        };

        // Tr[(I − n₁βW) M₂]
        let total_trace = m2.trace() - n1 * beta * w.trace_prod(&m2);

        -(n0 * n0 / 2.0) * (2.0 * std::f64::consts::PI).ln()
            - (n0 / 2.0) * logdet
            - (n0 * n1 / 2.0) * gam.ln()
            - total_trace / (2.0 * gam)
    }

    fn score_marg(&self, lx0: &Mat, lx1: &Mat, p: &CvParams) -> f64 {
        let n1 = lx1.rows as f64;
        let n0 = lx0.rows as f64;
        let (lam, gam) = (p.lambda, p.gamma);
        let c1 = 1.0 / (n1 * lam);

        let pm = lx1.t_matmul(lx1); // P
        let v = lx0.t_matmul(lx0); // V

        // Q̌ = I + c₁ P; log|Q̌| = log|I + c₁K̃ₓ¹| (Eq. 28); Ď = Q̌⁻¹.
        let q = pm.scale(c1).add_diag(1.0);
        let chq = Cholesky::new(&q).expect("Q̌ SPD");
        let logdet = chq.log_det();
        let dchk = chq.inverse();

        // Tr(K̃⁰) = Tr(V); Tr(K̃⁰¹B̌K̃¹⁰) = Tr(VP) − c₁Tr(VPĎP)  (Eq. 29-30)
        let vp = v.matmul(&pm);
        let tr_vp = vp.trace();
        let tr_vpdp = vp.matmul(&dchk).trace_prod(&pm);
        let trace_total = v.trace() - (tr_vp - c1 * tr_vpdp) / (n1 * gam);

        -(n0 * n0 / 2.0) * (2.0 * std::f64::consts::PI).ln()
            - (n0 / 2.0) * logdet
            - (n0 * n1 / 2.0) * gam.ln()
            - trace_total / (2.0 * gam)
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// Split a full-data factor into (test, train) fold factors, both
/// centered by the *train* column means (matching `cv_exact`).
pub fn split_center(lam: &Mat, test: &[usize], train: &[usize]) -> (Mat, Mat) {
    let m = lam.cols;
    let mut mean = vec![0.0; m];
    for &r in train {
        for c in 0..m {
            mean[c] += lam[(r, c)];
        }
    }
    for mc in &mut mean {
        *mc /= train.len() as f64;
    }
    let take = |rows: &[usize]| {
        let mut out = Mat::zeros(rows.len(), m);
        for (i, &r) in rows.iter().enumerate() {
            for c in 0..m {
                out[(i, c)] = lam[(r, c)] - mean[c];
            }
        }
        out
    };
    (take(test), take(train))
}

/// The CV-LR local score with per-variable/per-parent-set factor caching.
pub struct CvLrScore<K: CvLrKernel> {
    pub ds: Arc<Dataset>,
    pub params: CvParams,
    pub lr_cfg: LowRankConfig,
    pub backend: K,
    /// Low-rank factors keyed by the sorted variable set.
    factor_cache: Mutex<HashMap<Vec<usize>, Arc<Mat>>>,
}

impl CvLrScore<NativeCvLrKernel> {
    /// CV-LR with the native rust backend and paper-default parameters.
    pub fn native(ds: Arc<Dataset>) -> Self {
        CvLrScore::with_backend(ds, CvParams::default(), LowRankConfig::default(), NativeCvLrKernel)
    }
}

impl<K: CvLrKernel> CvLrScore<K> {
    pub fn with_backend(ds: Arc<Dataset>, params: CvParams, lr_cfg: LowRankConfig, backend: K) -> Self {
        CvLrScore { ds, params, lr_cfg, backend, factor_cache: Mutex::new(HashMap::new()) }
    }

    /// Low-rank factor of the kernel matrix of a variable set (Algorithm
    /// 2 for small-cardinality discrete sets, Algorithm 1 otherwise).
    pub fn factor_for(&self, vars: &[usize]) -> Arc<Mat> {
        let mut key: Vec<usize> = vars.to_vec();
        key.sort_unstable();
        if let Some(f) = self.factor_cache.lock().unwrap().get(&key) {
            return f.clone();
        }
        let block = self.ds.block_multi(&key);
        let kern = Kernel::Rbf { sigma: median_heuristic(&block, self.params.width_factor) };
        let LowRank { lambda, .. } =
            factorize(kern, &block, self.ds.all_discrete(&key), &self.lr_cfg);
        let arc = Arc::new(lambda);
        self.factor_cache.lock().unwrap().insert(key, arc.clone());
        arc
    }
}

/// Score one batch segment given an external factor source — the
/// machinery shared by [`CvLrScore`] (whose factors come from its
/// per-variable-set cache) and the streaming backend
/// (`stream::StreamBackend`, whose factors come from incrementally
/// maintained `FactorState`s). One centered (test, train) split per
/// unique variable set per fold, shared by every candidate in the
/// segment; per-request values are independent of how the caller
/// segments its batches.
pub fn score_segment_with<K: CvLrKernel>(
    n: usize,
    params: &CvParams,
    backend: &K,
    reqs: &[ScoreRequest],
    factor_for: &mut dyn FnMut(&[usize]) -> Arc<Mat>,
) -> Vec<f64> {
    let folds = stride_folds(n, params.folds);

    // Unique variable sets referenced by the batch: every target
    // singleton plus every non-empty parent set.
    let mut sets: Vec<Vec<usize>> = Vec::with_capacity(2 * reqs.len());
    for r in reqs {
        sets.push(vec![r.target]);
        if !r.parents.is_empty() {
            sets.push(r.parents.clone());
        }
    }
    sets.sort_unstable();
    sets.dedup();

    // One centered (test, train) split per set per fold, shared by
    // all candidates below.
    let mut splits: HashMap<Vec<usize>, Vec<(Mat, Mat)>> = HashMap::with_capacity(sets.len());
    for set in sets {
        let lam = factor_for(&set);
        let per_fold: Vec<(Mat, Mat)> =
            folds.iter().map(|(test, train)| split_center(&lam, test, train)).collect();
        splits.insert(set, per_fold);
    }

    let nfolds = folds.len() as f64;
    reqs.iter()
        .map(|r| {
            let lx = &splits[&[r.target][..]];
            if r.parents.is_empty() {
                let fs: Vec<MargFold<'_>> =
                    lx.iter().map(|(l0, l1)| MargFold { lx0: l0, lx1: l1 }).collect();
                backend.score_marg_batch(&fs, params).iter().sum::<f64>() / nfolds
            } else {
                let lz = &splits[&r.parents[..]];
                let fs: Vec<CondFold<'_>> = lx
                    .iter()
                    .zip(lz)
                    .map(|((x0, x1), (z0, z1))| CondFold { lx0: x0, lx1: x1, lz0: z0, lz1: z1 })
                    .collect();
                backend.score_cond_batch(&fs, params).iter().sum::<f64>() / nfolds
            }
        })
        .collect()
}

impl<K: CvLrKernel> CvLrScore<K> {
    /// One batch segment with fully shared per-set work (see
    /// `ScoreBackend::score_batch` below for the segmenting wrapper).
    fn score_segment(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        score_segment_with(self.ds.n(), &self.params, &self.backend, reqs, &mut |set: &[usize]| {
            self.factor_for(set)
        })
    }
}

impl<K: CvLrKernel> ScoreBackend for CvLrScore<K> {
    /// Batch-aware evaluation: the expensive per-variable-set work —
    /// low-rank factorization and per-fold train-mean centering — is
    /// done **once per unique set in a segment** and shared across
    /// every candidate that references it. A GES sweep scoring hundreds
    /// of parent-set variations of the same target pays for the target
    /// factor splits once per segment; the per-candidate cost collapses
    /// to the m×m core algebra, submitted to the fold kernel as one
    /// [`CvLrKernel::score_cond_batch`] call per candidate.
    ///
    /// Sweep-sized batches are processed in fixed segments so the
    /// transient centered-split storage stays bounded (at most ~2 ×
    /// segment variable sets live at once) no matter how wide the
    /// search batches get; per-request values are independent of the
    /// segmentation, so results stay bit-identical.
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        const SEGMENT: usize = 64;
        if reqs.len() <= SEGMENT {
            return self.score_segment(reqs);
        }
        reqs.chunks(SEGMENT).flat_map(|seg| self.score_segment(seg)).collect()
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

impl<K: CvLrKernel> LocalScore for CvLrScore<K> {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        // A one-request batch: keeps the scalar and batched paths on
        // the same code, so they are bit-identical by construction.
        self.score_batch(&[ScoreRequest::new(target, parents)])[0]
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::cv_exact::CvExactScore;
    use crate::util::Pcg64;

    fn continuous_ds(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = (x1 + 0.2 * rng.normal()).sin() + 0.2 * rng.normal();
            let x3 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
        }
        Arc::new(Dataset::from_columns(data, &[false, false, false]))
    }

    fn discrete_ds(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 2);
        for r in 0..n {
            let a = rng.below(3);
            let b = if rng.bernoulli(0.8) { a } else { rng.below(3) };
            data[(r, 0)] = a as f64;
            data[(r, 1)] = b as f64;
        }
        Arc::new(Dataset::from_columns(data, &[true, true]))
    }

    /// The Table-1 anchor: CV-LR must match exact CV to < 0.5% relative
    /// error on continuous data with m = 100.
    #[test]
    fn matches_exact_cv_continuous() {
        let ds = continuous_ds(150, 1);
        let exact = CvExactScore::new(ds.clone(), CvParams::default());
        let lr = CvLrScore::native(ds);
        for (target, parents) in [(1usize, vec![0usize]), (0, vec![]), (1, vec![0, 2])] {
            let se = exact.local_score(target, &parents);
            let sl = lr.local_score(target, &parents);
            let rel = ((se - sl) / se).abs();
            assert!(rel < 5e-3, "target {target} parents {parents:?}: exact {se} lr {sl} rel {rel}");
        }
    }

    /// Discrete data: Algorithm 2 is exact (Lemma 4.3) so CV-LR must
    /// match exact CV to numerical precision.
    #[test]
    fn matches_exact_cv_discrete_exactly() {
        let ds = discrete_ds(100, 2);
        let exact = CvExactScore::new(ds.clone(), CvParams::default());
        let lr = CvLrScore::native(ds);
        for (target, parents) in [(1usize, vec![0usize]), (0, vec![]), (1, vec![])] {
            let se = exact.local_score(target, &parents);
            let sl = lr.local_score(target, &parents);
            let rel = ((se - sl) / se).abs();
            assert!(rel < 1e-9, "target {target} parents {parents:?}: exact {se} lr {sl} rel {rel}");
        }
    }

    #[test]
    fn local_consistency_direction() {
        let ds = continuous_ds(200, 3);
        let lr = CvLrScore::native(ds);
        let dep = lr.local_score(1, &[0]);
        let marg = lr.local_score(1, &[]);
        assert!(dep > marg, "dependent parent must improve the score: {dep} vs {marg}");
        let ind_marg = lr.local_score(2, &[]);
        let ind_spur = lr.local_score(2, &[0]);
        assert!(ind_marg > ind_spur - 1.0, "spurious parent should not win big");
    }

    #[test]
    fn factor_cache_reused() {
        let ds = continuous_ds(80, 4);
        let lr = CvLrScore::native(ds);
        let f1 = lr.factor_for(&[0, 1]);
        let f2 = lr.factor_for(&[1, 0]); // different order, same set
        assert!(Arc::ptr_eq(&f1, &f2));
    }

    #[test]
    fn split_center_zero_means_on_train() {
        let mut rng = Pcg64::new(5);
        let lam = Mat::from_vec(20, 3, (0..60).map(|_| rng.normal()).collect());
        let test: Vec<usize> = (0..5).collect();
        let train: Vec<usize> = (5..20).collect();
        let (l0, l1) = split_center(&lam, &test, &train);
        assert_eq!(l0.rows, 5);
        assert_eq!(l1.rows, 15);
        for c in 0..3 {
            let s: f64 = (0..15).map(|r| l1[(r, c)]).sum();
            assert!(s.abs() < 1e-10, "train column {c} mean must be 0");
        }
    }

    /// Zero-column padding must not change the score — the invariance the
    /// fixed-shape XLA artifacts rely on (DESIGN.md §2).
    #[test]
    fn padding_invariance_native() {
        let ds = continuous_ds(100, 6);
        let lr = CvLrScore::native(ds);
        let lx = lr.factor_for(&[1]);
        let lz = lr.factor_for(&[0]);
        let folds = stride_folds(100, 10);
        let (test, train) = &folds[0];
        let (lx0, lx1) = split_center(&lx, test, train);
        let (lz0, lz1) = split_center(&lz, test, train);
        let k = NativeCvLrKernel;
        let s_ref = k.score_cond(&lx0, &lx1, &lz0, &lz1, &CvParams::default());
        let pad = |m: &Mat| m.pad_to(m.rows, m.cols + 7);
        let s_pad = k.score_cond(&pad(&lx0), &pad(&lx1), &pad(&lz0), &pad(&lz1), &CvParams::default());
        assert!(
            ((s_ref - s_pad) / s_ref).abs() < 1e-10,
            "column padding changed the score: {s_ref} vs {s_pad}"
        );
        let m_ref = k.score_marg(&lx0, &lx1, &CvParams::default());
        let m_pad = k.score_marg(&pad(&lx0), &pad(&lx1), &CvParams::default());
        assert!(((m_ref - m_pad) / m_ref).abs() < 1e-10);
    }
}
