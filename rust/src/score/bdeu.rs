//! BDeu score for discrete data (Buntine 1991, Heckerman et al. 1995)
//! with equivalent sample size n′ = 1 (the paper's setting §7.1).

use std::sync::Arc;

use super::LocalScore;
use crate::data::Dataset;
use crate::util::special::ln_gamma;

pub struct BdeuScore {
    pub ds: Arc<Dataset>,
    /// Equivalent sample size n′ (paper: 1.0).
    pub ess: f64,
}

impl BdeuScore {
    pub fn new(ds: Arc<Dataset>) -> Self {
        BdeuScore { ds, ess: 1.0 }
    }
}

impl LocalScore for BdeuScore {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        let ds = &self.ds;
        assert!(ds.vars[target].discrete, "BDeu requires discrete variables");
        let r_i = ds.vars[target].cardinality.max(1);
        // parent configuration count q_i
        let cards: Vec<usize> = parents.iter().map(|&p| ds.vars[p].cardinality.max(1)).collect();
        let q_i: usize = cards.iter().product::<usize>().max(1);

        // counts N_ijk
        let mut counts = vec![0u32; q_i * r_i];
        for row in 0..ds.n() {
            let mut j = 0usize;
            for (pi, &p) in parents.iter().enumerate() {
                j = j * cards[pi] + ds.level(p, row).min(cards[pi] - 1);
            }
            let k = ds.level(target, row).min(r_i - 1);
            counts[j * r_i + k] += 1;
        }

        let a_jk = self.ess / (r_i * q_i) as f64;
        let a_j = self.ess / q_i as f64;
        let mut score = 0.0;
        for j in 0..q_i {
            let n_j: u32 = counts[j * r_i..(j + 1) * r_i].iter().sum();
            if n_j == 0 {
                continue;
            }
            score += ln_gamma(a_j) - ln_gamma(a_j + n_j as f64);
            for k in 0..r_i {
                let n_jk = counts[j * r_i + k];
                if n_jk > 0 {
                    score += ln_gamma(a_jk + n_jk as f64) - ln_gamma(a_jk);
                }
            }
        }
        score
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::util::Pcg64;

    fn dep_ds(n: usize, seed: u64) -> Arc<Dataset> {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let a = rng.below(3);
            let b = if rng.bernoulli(0.85) { a } else { rng.below(3) };
            let c = rng.below(2);
            data[(r, 0)] = a as f64;
            data[(r, 1)] = b as f64;
            data[(r, 2)] = c as f64;
        }
        Arc::new(Dataset::from_columns(data, &[true, true, true]))
    }

    #[test]
    fn dependent_parent_wins() {
        let ds = dep_ds(400, 1);
        let s = BdeuScore::new(ds);
        assert!(s.local_score(1, &[0]) > s.local_score(1, &[]));
        assert!(s.local_score(1, &[0]) > s.local_score(1, &[2]));
    }

    #[test]
    fn independent_prefers_empty() {
        let ds = dep_ds(400, 2);
        let s = BdeuScore::new(ds);
        assert!(s.local_score(2, &[]) > s.local_score(2, &[0]));
    }

    #[test]
    fn score_equivalence_of_markov_equivalent_dags() {
        // A → B and B → A are Markov equivalent: BDeu totals must match.
        let ds = dep_ds(300, 3);
        let s = BdeuScore::new(ds);
        let ab = s.local_score(0, &[]) + s.local_score(1, &[0]);
        let ba = s.local_score(1, &[]) + s.local_score(0, &[1]);
        assert!((ab - ba).abs() < 1e-8, "BDeu must be score-equivalent: {ab} vs {ba}");
    }
}
