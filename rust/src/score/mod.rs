//! Score functions for causal discovery, behind a **batch-first** API.
//!
//! The paper's contribution is making each local score S(X|Z) cheap
//! (O(n m²) CV-LR via the low-rank dumbbell rules of §5); this module's
//! job is making sure the search layer can *exploit* that: every score
//! consumer speaks [`ScoreBackend::score_batch`], so a whole GES sweep
//! arrives at the backend as one wide request batch that can amortize
//! factor construction, fold splitting and device dispatch across
//! candidates.
//!
//! The two traits:
//!
//! * [`ScoreBackend`] — the primary interface: evaluate a slice of
//!   [`ScoreRequest`]s and return the scores in request order. The
//!   search (`search::ges`) and the coordinator's `ScoreService` both
//!   speak this trait and nothing else on the hot path.
//! * [`LocalScore`] — the scalar interface a score *implementation*
//!   provides: one decomposable local score `S(X_i | Pa_i)`. Any
//!   `LocalScore` becomes a (serial) `ScoreBackend` through the
//!   [`ScalarBackend`] adapter; batch-aware scores such as
//!   [`cvlr::CvLrScore`] implement `ScoreBackend` directly and share
//!   per-batch work across candidates.
//!
//! The score implementations:
//!
//! * [`cv_exact`] — the O(n³) cross-validated generalized score of Huang
//!   et al. (Eq. 8/9 of the paper) — the baseline "CV";
//! * [`cvlr`] — the paper's contribution: the same score computed from
//!   low-rank factors in O(n m²) via the dumbbell-form rules of §5
//!   ("CV-LR"). The m×m core algebra is expressed behind the
//!   [`cvlr::CvLrKernel`] trait so it can run natively (rust f64) or on
//!   the AOT-compiled XLA artifacts (see `runtime`); its per-fold
//!   centered cores come from the [`cores`] provider, which downdates
//!   them from one full-data Gram pass instead of recomputing per fold;
//! * [`marginal`] — the low-rank marginal-likelihood score;
//! * [`bic`], [`bdeu`], [`sc`] — the baseline scores of §7.1.
//!
//! Memoization lives in exactly one place: the coordinator's
//! `ScoreService` owns the single `ScoreCache`. Score implementations
//! stay cache-free (CV-LR's *factor* cache is not a score memo — it
//! caches per-variable-set kernel factors, a different key space).

pub mod folds;
pub mod cores;
pub mod cv_exact;
pub mod cvlr;
pub mod marginal;
pub mod bic;
pub mod bdeu;
pub mod sc;

/// One local-score request: S(target | parents).
///
/// Construction through [`ScoreRequest::new`] canonicalizes the parent
/// set (sorted ascending, duplicates removed), so two requests for the
/// same (target, parent-set) compare equal and hash identically no
/// matter how the caller ordered the parents.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ScoreRequest {
    pub target: usize,
    /// Sorted, deduplicated parent indices.
    pub parents: Vec<usize>,
}

impl ScoreRequest {
    /// Build a request with a canonicalized parent set.
    pub fn new(target: usize, parents: &[usize]) -> ScoreRequest {
        let mut p = parents.to_vec();
        p.sort_unstable();
        p.dedup();
        ScoreRequest { target, parents: p }
    }

    /// The memo-cache key for this request.
    pub fn key(&self) -> (usize, Vec<usize>) {
        (self.target, self.parents.clone())
    }
}

impl From<(usize, Vec<usize>)> for ScoreRequest {
    fn from((target, parents): (usize, Vec<usize>)) -> ScoreRequest {
        ScoreRequest::new(target, &parents)
    }
}

/// Aggregate counters of a sharding backend (`distrib`): how sub-batch
/// dispatch across the follower fleet went. All zero for local-only
/// backends. Surfaced through `ServiceStats` and `/v1/stats`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardCounters {
    /// Sub-batch requests sent to followers (every attempt counts).
    pub dispatches: u64,
    /// Re-dispatches after a failed attempt (bounded, backed off).
    pub retries: u64,
    /// Hedged re-dispatches of straggler sub-batches.
    pub hedges: u64,
    /// Sub-batches that fell back to local scoring.
    pub degraded: u64,
}

/// Point-in-time view of one follower in a shard pool: health, EWMA
/// latency, and its dispatch/retry/hedge/degrade counters. Rendered
/// per follower in `/v1/stats`.
#[derive(Clone, Debug)]
pub struct FollowerStat {
    pub addr: String,
    /// False while the consecutive-failure trip wire holds the
    /// follower out of rotation (re-probed periodically).
    pub healthy: bool,
    /// Exponentially-weighted moving average of request latency in
    /// milliseconds (0 until the first completed request).
    pub ewma_ms: f64,
    pub dispatches: u64,
    pub successes: u64,
    pub failures: u64,
    pub retries: u64,
    pub hedges: u64,
    pub degraded: u64,
}

/// A decomposable local score: higher is better.
pub trait LocalScore: Send + Sync {
    /// S(X_target | parents). `parents` must be sorted ascending
    /// (callers go through [`ScoreRequest`] / the coordinator's
    /// `ScoreService`, both of which canonicalize).
    fn local_score(&self, target: usize, parents: &[usize]) -> f64;

    /// Number of variables.
    fn num_vars(&self) -> usize;
}

/// The batch-first scoring interface — the primary API of the crate.
///
/// `score_batch` evaluates every request and returns the scores in
/// request order. Implementations are free to reorder, deduplicate or
/// fan out the *work* internally, but the result vector must line up
/// with `reqs` element-for-element and each score must be bit-identical
/// to what a one-request batch would return (the batch/scalar
/// equivalence invariant pinned by `tests/batch_equivalence.rs`).
pub trait ScoreBackend: Send + Sync {
    /// Evaluate a batch of local-score requests.
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64>;

    /// Number of variables.
    fn num_vars(&self) -> usize;

    /// Convenience scalar entry point: a one-request batch.
    fn score_one(&self, target: usize, parents: &[usize]) -> f64 {
        self.score_batch(&[ScoreRequest::new(target, parents)])[0]
    }

    /// `(resident entries, evictions)` of the backend's fold-core cache
    /// ([`cores::FoldCoreCache`]), `None` for backends without one.
    /// Surfaced through `ServiceStats::core_cache_entries` /
    /// `::core_cache_evictions` and `/v1/stats`, so the footprint of
    /// the per-set core bundles (~2× the factor cache per set) is
    /// observable in long-lived servers.
    fn core_cache_stats(&self) -> Option<(u64, u64)> {
        None
    }

    /// Resident heap bytes across the backend's core caches (fold-core
    /// + pair-core bundles + factor matrices), `None` for backends
    /// without one. The byte-accurate companion of
    /// [`ScoreBackend::core_cache_stats`]: entry counts bound
    /// *how many* bundles are resident, this bounds *how much* they
    /// weigh — surfaced through `ServiceStats::core_cache_bytes`,
    /// `/v1/stats`, and the `cvlr_service_core_cache_bytes` gauge.
    fn core_cache_bytes(&self) -> Option<u64> {
        None
    }

    /// Aggregate shard-dispatch counters (`distrib::ShardScoreBackend`),
    /// `None` for backends that score locally. Surfaced through
    /// `ServiceStats::shard_*` and `/v1/stats`.
    fn shard_counters(&self) -> Option<ShardCounters> {
        None
    }

    /// Per-follower health/latency/counter snapshots of a sharding
    /// backend; empty for local backends. Rendered per follower in
    /// `/v1/stats`.
    fn follower_stats(&self) -> Vec<FollowerStat> {
        Vec::new()
    }

    /// `(total re-pivots, appended-residual level summed over live
    /// factor states)` of a streaming backend (`stream::StreamBackend`),
    /// `None` otherwise. Surfaced through `ServiceStats::stream_*` and
    /// `/v1/stats` — the observables the adaptive re-tune roadmap item
    /// watches.
    fn stream_stats(&self) -> Option<(u64, f64)> {
        None
    }

    /// Install the deadline budget subsequent batches run under
    /// (`distrib::ShardScoreBackend` clamps dispatch/hedge/retry and
    /// socket timeouts by it; local backends have nothing to clamp).
    /// Pooled services outlive one run, so callers re-arm this per
    /// run/job — `Budget::none()` lifts the deadline again.
    fn set_budget(&self, _budget: crate::util::Budget) {}
}

/// Adapter turning any scalar [`LocalScore`] into a (serial)
/// [`ScoreBackend`]: the batch is evaluated one request at a time.
///
/// This is the compatibility bridge for score implementations with no
/// cross-candidate structure to share (BIC, BDeu, SC, exact CV);
/// batch-aware scores like [`cvlr::CvLrScore`] implement `ScoreBackend`
/// themselves instead.
pub struct ScalarBackend<S>(pub S);

impl<S: LocalScore> ScoreBackend for ScalarBackend<S> {
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        reqs.iter().map(|r| self.0.local_score(r.target, &r.parents)).collect()
    }

    fn num_vars(&self) -> usize {
        self.0.num_vars()
    }
}

impl<S: LocalScore> LocalScore for ScalarBackend<S> {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        self.0.local_score(target, parents)
    }

    fn num_vars(&self) -> usize {
        self.0.num_vars()
    }
}

impl<S: LocalScore + ?Sized> LocalScore for &S {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        (**self).local_score(target, parents)
    }

    fn num_vars(&self) -> usize {
        (**self).num_vars()
    }
}

/// Total score of a DAG given as a parent list (paper Eq. 31).
pub fn graph_score<S: LocalScore + ?Sized>(score: &S, parents: &[Vec<usize>]) -> f64 {
    parents
        .iter()
        .enumerate()
        .map(|(i, pa)| {
            let mut p = pa.clone();
            p.sort_unstable();
            score.local_score(i, &p)
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    struct CountingScore {
        calls: Mutex<usize>,
    }

    impl LocalScore for CountingScore {
        fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
            *self.calls.lock().unwrap() += 1;
            -(target as f64) - parents.len() as f64
        }
        fn num_vars(&self) -> usize {
            3
        }
    }

    #[test]
    fn request_canonicalizes_parents() {
        let a = ScoreRequest::new(1, &[2, 0, 2]);
        let b = ScoreRequest::new(1, &[0, 2]);
        assert_eq!(a, b);
        assert_eq!(a.key(), (1, vec![0, 2]));
    }

    #[test]
    fn scalar_backend_preserves_order_and_values() {
        let s = ScalarBackend(CountingScore { calls: Mutex::new(0) });
        let reqs = vec![
            ScoreRequest::new(2, &[0, 1]),
            ScoreRequest::new(0, &[]),
            ScoreRequest::new(1, &[2, 0]),
        ];
        let out = s.score_batch(&reqs);
        assert_eq!(out, vec![-4.0, 0.0, -3.0]);
        assert_eq!(s.score_one(2, &[1, 0]), -4.0);
        assert_eq!(*s.0.calls.lock().unwrap(), 4);
    }

    #[test]
    fn graph_score_sums_locals() {
        let s = CountingScore { calls: Mutex::new(0) };
        let total = graph_score(&s, &[vec![], vec![0], vec![0, 1]]);
        // -(0)-0 + -(1)-1 + -(2)-2 = -6
        assert_eq!(total, -6.0);
    }
}
