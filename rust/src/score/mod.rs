//! Score functions for causal discovery.
//!
//! * [`cv_exact`] — the O(n³) cross-validated generalized score of Huang
//!   et al. (Eq. 8/9 of the paper) — the baseline "CV";
//! * [`cvlr`] — the paper's contribution: the same score computed from
//!   low-rank factors in O(n m²) via the dumbbell-form rules of §5
//!   ("CV-LR"). The m×m core algebra is expressed behind the
//!   [`cvlr::CvLrKernel`] trait so it can run natively (rust f64) or on
//!   the AOT-compiled XLA artifacts (see `runtime`);
//! * [`bic`], [`bdeu`], [`sc`] — the baseline scores of §7.1;
//! * [`LocalScore`] — the common trait: a *decomposable* local score
//!   `S(X_i, Pa_i)`, summed over variables by [`graph_score`].

pub mod folds;
pub mod cv_exact;
pub mod cvlr;
pub mod marginal;
pub mod bic;
pub mod bdeu;
pub mod sc;

use std::collections::HashMap;
use std::sync::Mutex;

/// A decomposable local score: higher is better.
pub trait LocalScore: Send + Sync {
    /// S(X_target | parents). `parents` must be sorted ascending (callers
    /// go through [`CachedScore`] which normalizes).
    fn local_score(&self, target: usize, parents: &[usize]) -> f64;

    /// Number of variables.
    fn num_vars(&self) -> usize;
}

/// Total score of a DAG given as a parent list (paper Eq. 31).
pub fn graph_score<S: LocalScore + ?Sized>(score: &S, parents: &[Vec<usize>]) -> f64 {
    parents
        .iter()
        .enumerate()
        .map(|(i, pa)| {
            let mut p = pa.clone();
            p.sort_unstable();
            score.local_score(i, &p)
        })
        .sum()
}

/// Memoizing wrapper — the dedup cache used by GES, which re-evaluates
/// the same (target, parent-set) local score many times across
/// insert/delete candidates.
pub struct CachedScore<S> {
    pub inner: S,
    cache: Mutex<HashMap<(usize, Vec<usize>), f64>>,
    hits: Mutex<u64>,
    misses: Mutex<u64>,
}

impl<S: LocalScore> CachedScore<S> {
    pub fn new(inner: S) -> Self {
        CachedScore {
            inner,
            cache: Mutex::new(HashMap::new()),
            hits: Mutex::new(0),
            misses: Mutex::new(0),
        }
    }

    /// (hits, misses) counters — coordinator metrics.
    pub fn stats(&self) -> (u64, u64) {
        (*self.hits.lock().unwrap(), *self.misses.lock().unwrap())
    }
}

impl<S: LocalScore> LocalScore for CachedScore<S> {
    fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
        let mut key: Vec<usize> = parents.to_vec();
        key.sort_unstable();
        if let Some(&v) = self.cache.lock().unwrap().get(&(target, key.clone())) {
            *self.hits.lock().unwrap() += 1;
            return v;
        }
        let v = self.inner.local_score(target, &key);
        *self.misses.lock().unwrap() += 1;
        self.cache.lock().unwrap().insert((target, key), v);
        v
    }

    fn num_vars(&self) -> usize {
        self.inner.num_vars()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct CountingScore {
        calls: Mutex<usize>,
    }

    impl LocalScore for CountingScore {
        fn local_score(&self, target: usize, parents: &[usize]) -> f64 {
            *self.calls.lock().unwrap() += 1;
            -(target as f64) - parents.len() as f64
        }
        fn num_vars(&self) -> usize {
            3
        }
    }

    #[test]
    fn cache_deduplicates() {
        let s = CachedScore::new(CountingScore { calls: Mutex::new(0) });
        let a = s.local_score(1, &[0, 2]);
        let b = s.local_score(1, &[2, 0]); // unsorted — same set
        assert_eq!(a, b);
        assert_eq!(*s.inner.calls.lock().unwrap(), 1);
        let (h, m) = s.stats();
        assert_eq!((h, m), (1, 1));
    }

    #[test]
    fn graph_score_sums_locals() {
        let s = CountingScore { calls: Mutex::new(0) };
        let total = graph_score(&s, &[vec![], vec![0], vec![0, 1]]);
        // -(0)-0 + -(1)-1 + -(2)-2 = -6
        assert_eq!(total, -6.0);
    }
}
