//! The fold-core provider: every centered m×m core of the CV-LR score,
//! derived by **downdating** instead of per-fold recomputation.
//!
//! The old inner loop paid O(Q·n·m²) per candidate pair: for each of
//! the Q CV folds it materialized centered train/test factor copies
//! (`split_center`) and recomputed the six Gram cores (P, E, F, V, U,
//! S) from the n×m factors. But the fold test blocks *partition* the
//! rows of Λ, so
//!
//! ```text
//!   G_full = ΛᵀΛ = Σ_f Λ_fᵀΛ_f          (one pass over the data)
//!   G_train^f = G_full − Λ_fᵀΛ_f         (downdate, O(m²) per fold)
//! ```
//!
//! and train-mean centering is a rank-one correction of the uncentered
//! cores (with s = column sums, μ = s_train/n₁):
//!
//! ```text
//!   P^f = G_train^f − s_train s_trainᵀ / n₁
//!   V^f = G_test^f − s_test μᵀ − μ s_testᵀ + n₀ μμᵀ
//! ```
//!
//! (identically for the cross cores E/U of a (z, x) pair). The whole
//! per-pair cost collapses to **O(n·mz·mx) once** — the per-fold test
//! cross products, whose sum is the full cross Gram — plus O(Q·m²)
//! corrections; the per-set self cores are built once and cached
//! ([`FoldCoreCache`]) across every candidate, segment and sweep that
//! references the set, so a GES run scoring hundreds of parent-set
//! variations of one target pays for P/V exactly once.
//!
//! Parallelism: the per-fold Gram jobs (a row partition of Λ) are
//! distributed over a `std::thread::scope` pool gated on the
//! `parallelism` knob (`DiscoveryConfig::parallelism`); when threads
//! exceed the fold count, each job row-partitions its own block through
//! [`Mat::par_syrk`]/[`Mat::par_t_matmul`]. For `parallelism ≤ Q` the
//! results are bit-identical to the serial build (per-fold work is
//! serial and fold sums are accumulated in fold order).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::linalg::Mat;

/// Resolve the `parallelism` knob shared by every layer
/// (`DiscoveryConfig`/`StreamConfig`/`ServerConfig`/CLI/`POST
/// /v1/jobs`): `0` means **auto** — the machine's available
/// parallelism, capped at the fold count `q` (threads beyond Q only
/// help through the intra-fold row partition, which auto mode does not
/// assume is profitable). Any other value passes through unchanged.
pub fn resolve_parallelism(requested: usize, q: usize) -> usize {
    if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(q.max(1))
    } else {
        requested
    }
}

/// One conditional fold of centered cores, borrowed from the provider
/// caches: the complete input of the dumbbell-form score algebra
/// (`CvLrKernel::score_cond_cores`). Row counts travel explicitly —
/// cores carry no sample dimension.
pub struct CondCores<'a> {
    /// Train self-core of the target factor: P = Λ̃ₓ₁ᵀΛ̃ₓ₁ (mx×mx).
    pub p: &'a Mat,
    /// Train cross-core: E = Λ̃_z₁ᵀΛ̃ₓ₁ (mz×mx).
    pub e: &'a Mat,
    /// Train self-core of the parent factor: F = Λ̃_z₁ᵀΛ̃_z₁ (mz×mz).
    pub f: &'a Mat,
    /// Test self-core of the target factor: V = Λ̃ₓ₀ᵀΛ̃ₓ₀ (mx×mx).
    pub v: &'a Mat,
    /// Test cross-core: U = Λ̃_z₀ᵀΛ̃ₓ₀ (mz×mx).
    pub u: &'a Mat,
    /// Test self-core of the parent factor: S = Λ̃_z₀ᵀΛ̃_z₀ (mz×mz).
    pub s: &'a Mat,
    /// Test rows n₀ of the fold.
    pub n0: usize,
    /// Train rows n₁ of the fold.
    pub n1: usize,
}

/// One marginal fold of centered cores (`|z| = 0`).
pub struct MargCores<'a> {
    pub p: &'a Mat,
    pub v: &'a Mat,
    pub n0: usize,
    pub n1: usize,
}

/// Owned conditional cores — the straight-line reference path (centered
/// fold factors → direct `t_matmul` cores), kept for tests, the
/// factor-level `CvLrKernel` entry points, and cross-engine validation.
pub struct CondCoresBuf {
    pub p: Mat,
    pub e: Mat,
    pub f: Mat,
    pub v: Mat,
    pub u: Mat,
    pub s: Mat,
    pub n0: usize,
    pub n1: usize,
}

impl CondCoresBuf {
    /// Direct cores from factors already centered by the train mean
    /// (`split_center` output) — no downdating, the pre-provider path.
    pub fn from_centered_factors(lx0: &Mat, lx1: &Mat, lz0: &Mat, lz1: &Mat) -> CondCoresBuf {
        CondCoresBuf {
            p: lx1.t_matmul(lx1),
            e: lz1.t_matmul(lx1),
            f: lz1.t_matmul(lz1),
            v: lx0.t_matmul(lx0),
            u: lz0.t_matmul(lx0),
            s: lz0.t_matmul(lz0),
            n0: lx0.rows,
            n1: lx1.rows,
        }
    }

    pub fn view(&self) -> CondCores<'_> {
        CondCores {
            p: &self.p,
            e: &self.e,
            f: &self.f,
            v: &self.v,
            u: &self.u,
            s: &self.s,
            n0: self.n0,
            n1: self.n1,
        }
    }
}

/// Owned marginal cores (see [`CondCoresBuf`]).
pub struct MargCoresBuf {
    pub p: Mat,
    pub v: Mat,
    pub n0: usize,
    pub n1: usize,
}

impl MargCoresBuf {
    pub fn from_centered_factors(lx0: &Mat, lx1: &Mat) -> MargCoresBuf {
        MargCoresBuf {
            p: lx1.t_matmul(lx1),
            v: lx0.t_matmul(lx0),
            n0: lx0.rows,
            n1: lx1.rows,
        }
    }

    pub fn view(&self) -> MargCores<'_> {
        MargCores { p: &self.p, v: &self.v, n0: self.n0, n1: self.n1 }
    }
}

/// Everything the provider precomputes for ONE variable set: the fold
/// partition of its factor, the per-fold test Grams and column sums,
/// the full-data Gram (their sum), and the derived centered self-cores
/// P^f / V^f per fold. Built once per set by [`SetCores::build`] in
/// O(n·m²), cached by [`FoldCoreCache`].
pub struct SetCores {
    /// Per-fold uncentered test row blocks of the factor (the fold
    /// partition of Λ's rows) — retained for cross-core products.
    pub test_blocks: Vec<Mat>,
    /// Per-fold test-block column sums.
    pub test_colsum: Vec<Vec<f64>>,
    /// Full-data column sums (Σ over fold test blocks).
    pub colsum: Vec<f64>,
    /// Full-data Gram ΛᵀΛ (Σ over per-fold test Grams).
    pub gram: Mat,
    /// Per-fold test Grams Λ_fᵀΛ_f.
    pub test_gram: Vec<Mat>,
    /// Per-fold centered train self-cores P^f.
    pub train_self: Vec<Mat>,
    /// Per-fold centered test self-cores V^f (centered by the train
    /// mean, matching `split_center`).
    pub test_self: Vec<Mat>,
    /// Per-fold train means μ^f.
    pub train_mean: Vec<Vec<f64>>,
    /// Per-fold (n₀, n₁).
    pub sizes: Vec<(usize, usize)>,
}

/// Column sums of a matrix.
fn colsum(m: &Mat) -> Vec<f64> {
    let mut s = vec![0.0; m.cols];
    for r in 0..m.rows {
        for (acc, v) in s.iter_mut().zip(m.row(r)) {
            *acc += v;
        }
    }
    s
}

/// Evaluate `f(0..n_items)` on a scoped worker pool (`workers <= 1` is
/// a plain serial map). Items are claimed through an atomic counter;
/// results land in item order, so the output is independent of worker
/// interleaving.
fn par_map<T, F>(n_items: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let w = workers.min(n_items).max(1);
    if w <= 1 {
        return (0..n_items).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n_items);
    out.resize_with(n_items, || None);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..w)
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n_items {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("fold-core worker panicked") {
                out[i] = Some(v);
            }
        }
    });
    out.into_iter().map(|s| s.expect("every fold job completed")).collect()
}

impl SetCores {
    /// Build the self-cores of one variable set from its (uncentered)
    /// full-data factor and the CV fold assignment. O(n·m²) total: the
    /// per-fold test Grams (computed on the scoped pool, `threads`
    /// gated) sum to the full Gram, and every centered core is an
    /// O(m²) downdate + rank-one correction of them.
    pub fn build(lam: &Mat, folds: &[(Vec<usize>, Vec<usize>)], threads: usize) -> SetCores {
        let span = crate::obs::trace::span("fold-core-build", "score")
            .arg("m", lam.cols.to_string());
        let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::FoldCoreBuild);
        let sw = crate::util::Stopwatch::start();
        let m = lam.cols;
        let q = folds.len();
        assert!(q >= 2, "need at least 2 folds");
        let test_blocks: Vec<Mat> = folds.iter().map(|(test, _)| lam.select_rows(test)).collect();
        // fold jobs on the pool; intra-fold row partition only when
        // threads exceed the fold count
        let per_job = (threads / q).max(1);
        let grams: Vec<(Mat, Vec<f64>)> = par_map(q, threads, |fi| {
            let block = &test_blocks[fi];
            (block.par_syrk(per_job), colsum(block))
        });
        let mut gram = Mat::zeros(m, m);
        let mut colsum_full = vec![0.0; m];
        for (g, s) in &grams {
            for (a, b) in gram.data.iter_mut().zip(&g.data) {
                *a += b;
            }
            for (a, b) in colsum_full.iter_mut().zip(s) {
                *a += b;
            }
        }
        let mut test_gram = Vec::with_capacity(q);
        let mut test_colsum = Vec::with_capacity(q);
        for (g, s) in grams {
            test_gram.push(g);
            test_colsum.push(s);
        }

        let mut train_self = Vec::with_capacity(q);
        let mut test_self = Vec::with_capacity(q);
        let mut train_mean = Vec::with_capacity(q);
        let mut sizes = Vec::with_capacity(q);
        for f in 0..q {
            let n0 = folds[f].0.len();
            let n1 = folds[f].1.len();
            assert!(n1 > 0, "fold {f} has an empty train split");
            let n1f = n1 as f64;
            let n0f = n0 as f64;
            let g_te = &test_gram[f];
            let s_te = &test_colsum[f];
            let s_tr: Vec<f64> = colsum_full.iter().zip(s_te).map(|(a, b)| a - b).collect();
            let mu: Vec<f64> = s_tr.iter().map(|v| v / n1f).collect();
            // triangle-first so both cores are exactly symmetric
            let mut p = Mat::zeros(m, m);
            let mut v = Mat::zeros(m, m);
            for i in 0..m {
                for j in i..m {
                    p[(i, j)] = (gram[(i, j)] - g_te[(i, j)]) - s_tr[i] * s_tr[j] / n1f;
                    v[(i, j)] =
                        g_te[(i, j)] - s_te[i] * mu[j] - mu[i] * s_te[j] + n0f * mu[i] * mu[j];
                }
            }
            p.mirror_upper();
            v.mirror_upper();
            train_self.push(p);
            test_self.push(v);
            train_mean.push(mu);
            sizes.push((n0, n1));
        }
        crate::obs::metrics::fold_core_build_seconds().observe_with_exemplar(sw.secs(), span.id());
        SetCores {
            test_blocks,
            test_colsum,
            colsum: colsum_full,
            gram,
            test_gram,
            train_self,
            test_self,
            train_mean,
            sizes,
        }
    }

    /// Number of CV folds.
    pub fn num_folds(&self) -> usize {
        self.sizes.len()
    }

    /// Factor columns m.
    pub fn cols(&self) -> usize {
        self.gram.rows
    }

    /// Resident heap bytes of this bundle: every retained matrix buffer
    /// (fold test blocks, per-fold Grams, centered self-cores, the full
    /// Gram) plus the column-sum / train-mean vectors. Struct overhead
    /// (Vec headers, the `sizes` pairs) is negligible next to the
    /// O(n·m) fold blocks and is not counted.
    pub fn resident_bytes(&self) -> u64 {
        let mats = self
            .test_blocks
            .iter()
            .chain(self.test_gram.iter())
            .chain(self.train_self.iter())
            .chain(self.test_self.iter())
            .map(Mat::resident_bytes)
            .sum::<u64>()
            + self.gram.resident_bytes();
        let f64s = self
            .test_colsum
            .iter()
            .chain(self.train_mean.iter())
            .map(|v| v.capacity())
            .sum::<usize>()
            + self.colsum.capacity();
        mats + (f64s * std::mem::size_of::<f64>()) as u64
    }

    /// The marginal core view of fold `f`.
    pub fn marg_fold(&self, f: usize) -> MargCores<'_> {
        MargCores {
            p: &self.train_self[f],
            v: &self.test_self[f],
            n0: self.sizes[f].0,
            n1: self.sizes[f].1,
        }
    }
}

/// The centered cross-cores E^f / U^f of one (parent-set z, target x)
/// pair — the only per-pair full-data work left: O(n·mz·mx) of per-fold
/// test cross products (whose sum is the full cross Gram) plus O(Q·mz·mx)
/// corrections.
pub struct PairCores {
    /// Per-fold centered train cross-cores E^f (mz×mx).
    pub train_cross: Vec<Mat>,
    /// Per-fold centered test cross-cores U^f (mz×mx).
    pub test_cross: Vec<Mat>,
}

impl PairCores {
    /// Resident heap bytes of the per-fold cross-core matrices.
    pub fn resident_bytes(&self) -> u64 {
        self.train_cross
            .iter()
            .chain(self.test_cross.iter())
            .map(Mat::resident_bytes)
            .sum()
    }
}

/// Build the cross-cores of a (z, x) pair from their self-core caches.
/// Both must have been built over the same fold assignment (the
/// provider guarantees it — folds are a function of (n, Q) only).
pub fn pair_cores(z: &SetCores, x: &SetCores, threads: usize) -> PairCores {
    let _span = crate::obs::trace::span("pair-cores", "score");
    let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::PairCores);
    let q = z.num_folds();
    assert_eq!(q, x.num_folds(), "pair_cores needs matching fold counts");
    let (mz, mx) = (z.cols(), x.cols());
    let per_job = (threads / q).max(1);
    let c_test: Vec<Mat> =
        par_map(q, threads, |f| z.test_blocks[f].par_t_matmul(&x.test_blocks[f], per_job));
    let mut c_full = Mat::zeros(mz, mx);
    for c in &c_test {
        for (a, b) in c_full.data.iter_mut().zip(&c.data) {
            *a += b;
        }
    }
    let mut train_cross = Vec::with_capacity(q);
    let mut test_cross = Vec::with_capacity(q);
    for f in 0..q {
        let (n0, n1) = z.sizes[f];
        debug_assert_eq!((n0, n1), x.sizes[f], "fold assignments diverged");
        let n1f = n1 as f64;
        let n0f = n0 as f64;
        let sz_tr: Vec<f64> =
            z.colsum.iter().zip(&z.test_colsum[f]).map(|(a, b)| a - b).collect();
        let sx_tr: Vec<f64> =
            x.colsum.iter().zip(&x.test_colsum[f]).map(|(a, b)| a - b).collect();
        let (mu_z, mu_x) = (&z.train_mean[f], &x.train_mean[f]);
        let (sz_te, sx_te) = (&z.test_colsum[f], &x.test_colsum[f]);
        let ct = &c_test[f];
        let mut e = Mat::zeros(mz, mx);
        let mut u = Mat::zeros(mz, mx);
        for i in 0..mz {
            for j in 0..mx {
                e[(i, j)] = (c_full[(i, j)] - ct[(i, j)]) - sz_tr[i] * sx_tr[j] / n1f;
                u[(i, j)] =
                    ct[(i, j)] - sz_te[i] * mu_x[j] - mu_z[i] * sx_te[j] + n0f * mu_z[i] * mu_x[j];
            }
        }
        train_cross.push(e);
        test_cross.push(u);
    }
    PairCores { train_cross, test_cross }
}

/// The conditional core view of fold `f` for a (z, x) pair.
pub fn cond_fold<'a>(
    x: &'a SetCores,
    z: &'a SetCores,
    pair: &'a PairCores,
    f: usize,
) -> CondCores<'a> {
    CondCores {
        p: &x.train_self[f],
        e: &pair.train_cross[f],
        f: &z.train_self[f],
        v: &x.test_self[f],
        u: &pair.test_cross[f],
        s: &z.test_self[f],
        n0: x.sizes[f].0,
        n1: x.sizes[f].1,
    }
}

/// One resident fold-core bundle plus its second-chance (clock) bit,
/// set on every hit. Values are `Arc`-shared, so eviction only drops
/// the cache's reference — in-flight scorers keep theirs.
struct CoreSlot {
    cores: Arc<SetCores>,
    referenced: bool,
}

#[derive(Default)]
struct CoreCacheInner {
    map: HashMap<Vec<usize>, CoreSlot>,
    /// Clock queue over resident keys, oldest first; each resident key
    /// appears at most once (inserts enqueue, evictions pop).
    ring: VecDeque<Vec<usize>>,
    evictions: u64,
}

/// Per-variable-set self-core cache, keyed by the sorted variable set.
/// One [`SetCores::build`] per set per dataset version: `CvLrScore`
/// keeps it for the life of the score, the streaming backend clears it
/// on every append (every core depends on every row).
///
/// The cache can be **bounded** ([`FoldCoreCache::with_capacity`]),
/// mirroring the score memo layer's second-chance eviction
/// (`ScoreCache::with_capacity`): each `SetCores` retains the fold
/// blocks — roughly 2× the factor-cache footprint per set — which is
/// fine for one run but grows without limit across wide pooled-server
/// sweeps. Entry and eviction counts are surfaced through
/// `ScoreBackend::core_cache_stats` into `ServiceStats` / `/v1/stats`;
/// the server defaults the bound from its `cache_capacity`.
#[derive(Default)]
pub struct FoldCoreCache {
    inner: Mutex<CoreCacheInner>,
    /// Maximum resident entries (None = unbounded).
    capacity: Option<usize>,
}

impl FoldCoreCache {
    /// Unbounded cache (the one-shot CLI default).
    pub fn new() -> FoldCoreCache {
        FoldCoreCache::default()
    }

    /// Cache holding at most `capacity` entries (None = unbounded).
    pub fn with_capacity(capacity: Option<usize>) -> FoldCoreCache {
        FoldCoreCache { inner: Mutex::new(CoreCacheInner::default()), capacity }
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Entries reclaimed by the second-chance sweep so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Cached self-cores for `key` (must be sorted), if resident — the
    /// fast path for callers that want to skip assembling build inputs
    /// (fold vectors) on a hit. Sets the entry's second-chance bit.
    pub fn get(&self, key: &[usize]) -> Option<Arc<SetCores>> {
        let mut inner = self.inner.lock().unwrap();
        inner.map.get_mut(key).map(|slot| {
            slot.referenced = true;
            slot.cores.clone()
        })
    }

    /// Cached self-cores for `key` (must be sorted), building from the
    /// factor on a miss. The build runs OUTSIDE the lock — the O(n·m²)
    /// work must not serialize concurrent score workers; racing
    /// builders of the same set: first insert wins. A bounded cache
    /// sweeps after the insert.
    pub fn get_or_build(
        &self,
        key: &[usize],
        folds: &[(Vec<usize>, Vec<usize>)],
        threads: usize,
        factor: &mut dyn FnMut() -> Arc<Mat>,
    ) -> Arc<SetCores> {
        if let Some(c) = self.get(key) {
            return c;
        }
        let lam = factor();
        let cores = Arc::new(SetCores::build(&lam, folds, threads));
        let mut inner = self.inner.lock().unwrap();
        let out = match inner.map.get_mut(key) {
            // racing builder won: serve its entry, drop ours
            Some(slot) => {
                slot.referenced = true;
                slot.cores.clone()
            }
            None => {
                inner
                    .map
                    .insert(key.to_vec(), CoreSlot { cores: cores.clone(), referenced: false });
                inner.ring.push_back(key.to_vec());
                cores
            }
        };
        if let Some(cap) = self.capacity {
            Self::enforce_capacity(&mut inner, cap);
        }
        out
    }

    /// Second-chance sweep: pop the oldest resident entry; referenced
    /// entries spend their bit and requeue, unreferenced ones are
    /// reclaimed (outstanding `Arc`s stay valid — only the cache's
    /// reference is dropped). Budgeted so it always terminates.
    fn enforce_capacity(inner: &mut CoreCacheInner, cap: usize) {
        let mut budget = 2 * inner.ring.len();
        while inner.map.len() > cap && budget > 0 {
            budget -= 1;
            let k = match inner.ring.pop_front() {
                Some(k) => k,
                None => break,
            };
            match inner.map.get_mut(&k) {
                Some(slot) if slot.referenced => {
                    slot.referenced = false;
                    inner.ring.push_back(k);
                }
                Some(_) => {
                    inner.map.remove(&k);
                    inner.evictions += 1;
                }
                // stale ring key (cleared between enqueue and sweep)
                None => {}
            }
        }
    }

    /// Drop every cached entry (dataset rows changed); returns how many
    /// were resident. Cleared entries are not counted as evictions —
    /// invalidation is not capacity pressure.
    pub fn clear(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.map.len();
        inner.map.clear();
        inner.ring.clear();
        n
    }

    /// Resident variable sets.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident heap bytes across every cached bundle (matrix buffers
    /// plus key vectors) — walked under the lock, so keep callers on
    /// stats paths, not hot score paths.
    pub fn resident_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .map
            .iter()
            .map(|(k, slot)| {
                slot.cores.resident_bytes()
                    + (k.capacity() * std::mem::size_of::<usize>()) as u64
            })
            .sum()
    }
}

/// One resident cross-core bundle plus its second-chance bit.
struct PairSlot {
    cores: Arc<PairCores>,
    referenced: bool,
}

#[derive(Default)]
struct PairCacheInner {
    map: HashMap<(usize, Vec<usize>), PairSlot>,
    /// Clock queue over resident keys, oldest first; each resident key
    /// appears at most once (inserts enqueue, evictions pop).
    ring: VecDeque<(usize, Vec<usize>)>,
    evictions: u64,
}

/// Cross-segment cache of the per-pair E/U cross-cores, keyed by
/// (target, sorted parent set) — the [`FoldCoreCache`] twin for
/// [`PairCores`]. GES re-scores the same (parents → target) pair far
/// beyond one batch segment: neighbor re-evaluations repeat across
/// sweeps, and the memo layer only absorbs *exact* request repeats
/// after the score cache survives. Without this cache every
/// re-appearance of a pair in a new segment repays the O(n·mz·mx)
/// cross-product pass even though both self-core bundles are resident.
/// Bounded with the same second-chance (clock) eviction as the
/// self-core cache; owners clear it whenever the dataset rows change
/// (every core depends on every row).
#[derive(Default)]
pub struct PairCoreCache {
    inner: Mutex<PairCacheInner>,
    /// Maximum resident entries (None = unbounded).
    capacity: Option<usize>,
}

impl PairCoreCache {
    /// Unbounded cache (the one-shot CLI default).
    pub fn new() -> PairCoreCache {
        PairCoreCache::default()
    }

    /// Cache holding at most `capacity` entries (None = unbounded).
    pub fn with_capacity(capacity: Option<usize>) -> PairCoreCache {
        PairCoreCache { inner: Mutex::new(PairCacheInner::default()), capacity }
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Entries reclaimed by the second-chance sweep so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Cached cross-cores for (target, parents), if resident. `parents`
    /// must be sorted (`ScoreRequest` canonicalizes). Sets the entry's
    /// second-chance bit.
    pub fn get(&self, target: usize, parents: &[usize]) -> Option<Arc<PairCores>> {
        self.get_key(&(target, parents.to_vec()))
    }

    fn get_key(&self, key: &(usize, Vec<usize>)) -> Option<Arc<PairCores>> {
        let mut inner = self.inner.lock().unwrap();
        inner.map.get_mut(key).map(|slot| {
            slot.referenced = true;
            slot.cores.clone()
        })
    }

    /// Cached cross-cores for (target, parents), building from the two
    /// self-core bundles on a miss. The O(n·mz·mx) build runs OUTSIDE
    /// the lock; racing builders of the same pair: first insert wins. A
    /// bounded cache sweeps after the insert.
    pub fn get_or_build(
        &self,
        target: usize,
        parents: &[usize],
        z: &SetCores,
        x: &SetCores,
        threads: usize,
    ) -> Arc<PairCores> {
        let key = (target, parents.to_vec());
        if let Some(c) = self.get_key(&key) {
            return c;
        }
        let built = Arc::new(pair_cores(z, x, threads));
        let mut inner = self.inner.lock().unwrap();
        let out = match inner.map.get_mut(&key) {
            // racing builder won: serve its entry, drop ours
            Some(slot) => {
                slot.referenced = true;
                slot.cores.clone()
            }
            None => {
                inner
                    .map
                    .insert(key.clone(), PairSlot { cores: built.clone(), referenced: false });
                inner.ring.push_back(key);
                built
            }
        };
        if let Some(cap) = self.capacity {
            Self::enforce_capacity(&mut inner, cap);
        }
        out
    }

    /// Second-chance sweep — same discipline as the self-core cache:
    /// referenced entries spend their bit and requeue, unreferenced
    /// ones are reclaimed; budgeted so it always terminates.
    fn enforce_capacity(inner: &mut PairCacheInner, cap: usize) {
        let mut budget = 2 * inner.ring.len();
        while inner.map.len() > cap && budget > 0 {
            budget -= 1;
            let k = match inner.ring.pop_front() {
                Some(k) => k,
                None => break,
            };
            match inner.map.get_mut(&k) {
                Some(slot) if slot.referenced => {
                    slot.referenced = false;
                    inner.ring.push_back(k);
                }
                Some(_) => {
                    inner.map.remove(&k);
                    inner.evictions += 1;
                }
                None => {}
            }
        }
    }

    /// Drop every cached entry (dataset rows changed); returns how many
    /// were resident. Not counted as evictions.
    pub fn clear(&self) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let n = inner.map.len();
        inner.map.clear();
        inner.ring.clear();
        n
    }

    /// Resident (target, parents) pairs.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Resident heap bytes across every cached bundle (matrix buffers
    /// plus parent-key vectors) — walked under the lock; stats paths
    /// only.
    pub fn resident_bytes(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .map
            .iter()
            .map(|((_, parents), slot)| {
                slot.cores.resident_bytes()
                    + (parents.capacity() * std::mem::size_of::<usize>()) as u64
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::cvlr::split_center;
    use crate::score::folds::stride_folds;
    use crate::util::Pcg64;

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(rows, cols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    /// Downdated self-cores equal the split_center reference cores.
    #[test]
    fn set_cores_match_split_center_reference() {
        let lam = random_mat(53, 4, 1);
        let folds = stride_folds(53, 5);
        let cores = SetCores::build(&lam, &folds, 1);
        assert_eq!(cores.num_folds(), 5);
        for (f, (test, train)) in folds.iter().enumerate() {
            let (l0, l1) = split_center(&lam, test, train);
            let p_ref = l1.t_matmul(&l1);
            let v_ref = l0.t_matmul(&l0);
            assert!(
                (&cores.train_self[f] - &p_ref).max_abs() < 1e-10,
                "P mismatch on fold {f}"
            );
            assert!(
                (&cores.test_self[f] - &v_ref).max_abs() < 1e-10,
                "V mismatch on fold {f}"
            );
            assert_eq!(cores.sizes[f], (test.len(), train.len()));
        }
        // the fold test Grams sum to the full Gram
        let full = lam.t_matmul(&lam);
        assert!((&cores.gram - &full).max_abs() < 1e-10);
    }

    /// Downdated cross-cores equal the split_center reference cores.
    #[test]
    fn pair_cores_match_split_center_reference() {
        let lz = random_mat(47, 3, 2);
        let lx = random_mat(47, 5, 3);
        let folds = stride_folds(47, 4);
        let z = SetCores::build(&lz, &folds, 1);
        let x = SetCores::build(&lx, &folds, 1);
        let pair = pair_cores(&z, &x, 1);
        for (f, (test, train)) in folds.iter().enumerate() {
            let (lz0, lz1) = split_center(&lz, test, train);
            let (lx0, lx1) = split_center(&lx, test, train);
            let e_ref = lz1.t_matmul(&lx1);
            let u_ref = lz0.t_matmul(&lx0);
            assert!(
                (&pair.train_cross[f] - &e_ref).max_abs() < 1e-10,
                "E mismatch on fold {f}"
            );
            assert!(
                (&pair.test_cross[f] - &u_ref).max_abs() < 1e-10,
                "U mismatch on fold {f}"
            );
        }
    }

    /// For parallelism ≤ Q the build is bit-identical to serial (per-
    /// fold work stays serial, fold sums accumulate in fold order).
    #[test]
    fn build_is_deterministic_across_thread_counts() {
        let lam = random_mat(80, 6, 4);
        let folds = stride_folds(80, 5);
        let serial = SetCores::build(&lam, &folds, 1);
        for threads in [2usize, 4, 5] {
            let par = SetCores::build(&lam, &folds, threads);
            assert_eq!(par.gram.data, serial.gram.data, "threads={threads}");
            for f in 0..5 {
                assert_eq!(par.train_self[f].data, serial.train_self[f].data);
                assert_eq!(par.test_self[f].data, serial.test_self[f].data);
            }
        }
        let lx = random_mat(80, 3, 5);
        let x1 = SetCores::build(&lx, &folds, 1);
        let p1 = pair_cores(&serial, &x1, 1);
        let p4 = pair_cores(&serial, &x1, 4);
        for f in 0..5 {
            assert_eq!(p1.train_cross[f].data, p4.train_cross[f].data);
            assert_eq!(p1.test_cross[f].data, p4.test_cross[f].data);
        }
    }

    #[test]
    fn fold_core_cache_builds_once_and_clears() {
        let lam = Arc::new(random_mat(40, 3, 6));
        let folds = stride_folds(40, 4);
        let cache = FoldCoreCache::new();
        let builds = std::cell::Cell::new(0usize);
        let mut factor = || {
            builds.set(builds.get() + 1);
            lam.clone()
        };
        let a = cache.get_or_build(&[0, 2], &folds, 1, &mut factor);
        let b = cache.get_or_build(&[0, 2], &folds, 1, &mut factor);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        assert_eq!(builds.get(), 1, "the factor is pulled once per set");
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.clear(), 1);
        assert!(cache.is_empty());
        let _ = cache.get_or_build(&[0, 2], &folds, 1, &mut factor);
        assert_eq!(builds.get(), 2, "cleared entries rebuild");
    }

    #[test]
    fn bounded_core_cache_evicts_second_chance() {
        let lam = Arc::new(random_mat(40, 3, 7));
        let folds = stride_folds(40, 4);
        let cache = FoldCoreCache::with_capacity(Some(2));
        assert_eq!(cache.capacity(), Some(2));
        let mut factor = || lam.clone();
        cache.get_or_build(&[0], &folds, 1, &mut factor); // A
        cache.get_or_build(&[1], &folds, 1, &mut factor); // B
        assert!(cache.get(&[0]).is_some()); // hit A → referenced
        cache.get_or_build(&[2], &folds, 1, &mut factor); // sweep: spares A, evicts B
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&[0]).is_some(), "referenced entry survived the sweep");
        assert!(cache.get(&[1]).is_none(), "B was the victim");
        // an evicted set rebuilds on demand
        let builds = std::cell::Cell::new(0usize);
        let mut counting = || {
            builds.set(builds.get() + 1);
            lam.clone()
        };
        cache.get_or_build(&[1], &folds, 1, &mut counting);
        assert_eq!(builds.get(), 1, "evicted entries rebuild");
        // clears are invalidations, not evictions
        cache.clear();
        assert_eq!(cache.evictions(), 2, "the rebuild of [1] evicted one more entry");
        assert!(cache.is_empty());
    }

    #[test]
    fn unbounded_core_cache_never_evicts() {
        let lam = Arc::new(random_mat(30, 2, 8));
        let folds = stride_folds(30, 3);
        let cache = FoldCoreCache::new();
        let mut factor = || lam.clone();
        for k in 0..10usize {
            cache.get_or_build(&[k], &folds, 1, &mut factor);
        }
        assert_eq!(cache.len(), 10);
        assert_eq!(cache.evictions(), 0);
    }

    #[test]
    fn pair_core_cache_reuses_and_clears() {
        let folds = stride_folds(40, 4);
        let z = SetCores::build(&random_mat(40, 3, 20), &folds, 1);
        let x = SetCores::build(&random_mat(40, 2, 21), &folds, 1);
        let cache = PairCoreCache::new();
        let a = cache.get_or_build(1, &[0, 2], &z, &x, 1);
        let b = cache.get_or_build(1, &[0, 2], &z, &x, 1);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        assert_eq!(cache.len(), 1);
        // the cached bundle is the real pair_cores output
        let want = pair_cores(&z, &x, 1);
        for f in 0..folds.len() {
            assert_eq!(a.train_cross[f].data, want.train_cross[f].data);
            assert_eq!(a.test_cross[f].data, want.test_cross[f].data);
        }
        assert_eq!(cache.clear(), 1);
        assert!(cache.is_empty());
        assert_eq!(cache.evictions(), 0, "clears are not evictions");
    }

    #[test]
    fn bounded_pair_cache_evicts_second_chance() {
        let folds = stride_folds(30, 3);
        let z = SetCores::build(&random_mat(30, 2, 22), &folds, 1);
        let x = SetCores::build(&random_mat(30, 2, 23), &folds, 1);
        let cache = PairCoreCache::with_capacity(Some(2));
        cache.get_or_build(0, &[1], &z, &x, 1); // A
        cache.get_or_build(1, &[2], &z, &x, 1); // B
        assert!(cache.get(0, &[1]).is_some()); // hit A → referenced
        cache.get_or_build(2, &[0], &z, &x, 1); // sweep: spares A, evicts B
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(0, &[1]).is_some(), "referenced entry survived");
        assert!(cache.get(1, &[2]).is_none(), "B was the victim");
    }

    /// Byte accounting covers every retained buffer and tracks cache
    /// population: at minimum the fold blocks (n·m doubles) plus the
    /// full Gram, and a cleared cache reports zero.
    #[test]
    fn resident_bytes_track_cache_population() {
        let lam = Arc::new(random_mat(40, 3, 30));
        let folds = stride_folds(40, 4);
        let cores = SetCores::build(&lam, &folds, 1);
        let floor = (40 * 3 + 3 * 3) * std::mem::size_of::<f64>() as u64;
        assert!(
            cores.resident_bytes() >= floor,
            "SetCores must count at least the fold blocks + Gram ({} < {floor})",
            cores.resident_bytes()
        );
        let cache = FoldCoreCache::new();
        assert_eq!(cache.resident_bytes(), 0);
        let mut factor = || lam.clone();
        cache.get_or_build(&[0, 1], &folds, 1, &mut factor);
        let one = cache.resident_bytes();
        assert!(one >= cores.resident_bytes(), "cache counts the full bundle");
        cache.get_or_build(&[2], &folds, 1, &mut factor);
        assert!(cache.resident_bytes() > one, "bytes grow with residency");
        cache.clear();
        assert_eq!(cache.resident_bytes(), 0, "cleared caches report zero");

        let z = SetCores::build(&random_mat(40, 2, 31), &folds, 1);
        let pcache = PairCoreCache::new();
        assert_eq!(pcache.resident_bytes(), 0);
        let bundle = pcache.get_or_build(0, &[1], &z, &cores, 1);
        assert!(pcache.resident_bytes() >= bundle.resident_bytes());
        assert!(bundle.resident_bytes() >= (2 * 4 * 2 * 3 * 8) as u64);
    }

    #[test]
    fn resolve_parallelism_auto_and_passthrough() {
        // explicit values pass through untouched
        assert_eq!(resolve_parallelism(1, 10), 1);
        assert_eq!(resolve_parallelism(7, 10), 7);
        assert_eq!(resolve_parallelism(64, 10), 64, "explicit values are not capped");
        // auto: at least 1, at most Q
        let auto = resolve_parallelism(0, 10);
        assert!((1..=10).contains(&auto), "auto resolved to {auto}");
        assert_eq!(resolve_parallelism(0, 1), 1, "Q caps the auto value");
    }
}
