//! Conditional-independence testing for the constraint-based baselines.

pub mod kci;

pub use kci::{CiTest, Kci};
