//! Conditional-independence testing for the constraint-based baselines,
//! plus the repo-invariant lint pass (`cvlr lint`, see [`lint`]).

pub mod kci;
pub mod lint;

pub use kci::{CiTest, Kci};
