//! Kernel-based conditional independence test (KCI, Zhang et al. 2011),
//! as used by the PC and MM-MB baselines in §7.1.
//!
//! * Unconditional: statistic `Tr(K̃ₓ K̃_y)` with the gamma approximation
//!   of the null (mean/variance matched from kernel traces).
//! * Conditional: residualized kernels `K̈ = R_z K̃ R_z` with
//!   `R_z = ε(K̃_z + εI)⁻¹`, statistic `Tr(K̈ₓ K̈_y)`, null approximated by
//!   a gamma fit to the weighted-chi-square spectrum (eigenvalue products
//!   of the residual kernels) — the `approx=True` path of the reference
//!   implementation. X is augmented with Z/2 before computing K̃ₓ, as in
//!   the reference.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::data::Dataset;
use crate::kernel::{center_gram, gram, median_heuristic, Kernel};
use crate::linalg::{sym_eig, Cholesky, Mat};
use crate::util::special::gamma_sf;

/// A conditional-independence test over dataset variables.
pub trait CiTest: Send + Sync {
    /// p-value for X_i ⊥ X_j | X_S.
    fn pvalue(&self, i: usize, j: usize, cond: &[usize]) -> f64;
    fn num_vars(&self) -> usize;
}

/// KCI test with p-value caching.
pub struct Kci {
    pub ds: Arc<Dataset>,
    /// Ridge ε of the residualizing operator R_z (reference: 1e-3).
    pub epsilon: f64,
    /// Kernel width factor over the median distance (PC setting: 1.0).
    pub width_factor: f64,
    cache: Mutex<HashMap<(usize, usize, Vec<usize>), f64>>,
    /// Test-invocation counter (coordinator metrics).
    calls: Mutex<u64>,
}

impl Kci {
    pub fn new(ds: Arc<Dataset>) -> Kci {
        Kci {
            ds,
            epsilon: 1e-3,
            width_factor: 1.0,
            cache: Mutex::new(HashMap::new()),
            calls: Mutex::new(0),
        }
    }

    pub fn calls(&self) -> u64 {
        *self.calls.lock().unwrap()
    }

    fn centered_kernel(&self, block: &Mat) -> Mat {
        let k = Kernel::Rbf { sigma: median_heuristic(block, self.width_factor) };
        center_gram(&gram(k, block))
    }

    /// Unconditional KCI via the gamma approximation.
    fn test_unconditional(&self, x: &Mat, y: &Mat) -> f64 {
        let n = x.rows as f64;
        let kx = self.centered_kernel(x);
        let ky = self.centered_kernel(y);
        let sta = kx.frob_dot(&ky); // Tr(K̃x K̃y) — both symmetric
        let mean = kx.trace() * ky.trace() / n;
        let var = 2.0 * kx.frob_dot(&kx) * ky.frob_dot(&ky) / (n * n);
        if mean <= 0.0 || var <= 0.0 {
            return 1.0;
        }
        let k_shape = mean * mean / var;
        let theta = var / mean;
        gamma_sf(sta, k_shape, theta).clamp(0.0, 1.0)
    }

    /// Conditional KCI via residual kernels + spectral gamma fit.
    fn test_conditional(&self, x: &Mat, y: &Mat, z: &Mat) -> f64 {
        let n = x.rows;
        // augment x with z/2 (reference implementation)
        let xz = x.hcat(&z.scale(0.5));
        let kx = self.centered_kernel(&xz);
        let ky = self.centered_kernel(y);
        let kz = self.centered_kernel(z);

        // R_z = ε (K̃_z + εI)⁻¹
        let eps = self.epsilon * n as f64 * 1e-0; // scale-free enough; ref uses fixed 1e-3·I on normalized kernels
        let rz = Cholesky::new(&kz.add_diag(eps))
            .expect("K̃z + εI SPD")
            .inverse()
            .scale(eps);
        let kxr = rz.matmul(&kx).matmul(&rz);
        let kyr = rz.matmul(&ky).matmul(&rz);
        let sta = kxr.frob_dot(&kyr);

        // spectral gamma fit: eigenvalue products of the residual kernels
        let (wx, vx) = sym_eig(&kxr);
        let (wy, vy) = sym_eig(&kyr);
        let thresh_x = wx.first().cloned().unwrap_or(0.0) * 1e-5;
        let thresh_y = wy.first().cloned().unwrap_or(0.0) * 1e-5;
        let keep = |w: &[f64], t: f64, cap: usize| -> Vec<usize> {
            w.iter().enumerate().filter(|(_, &v)| v > t && v > 0.0).map(|(i, _)| i).take(cap).collect()
        };
        // cap products so uu has at most ~512 columns
        let ix = keep(&wx, thresh_x, 24);
        let iy = keep(&wy, thresh_y, 24);
        if ix.is_empty() || iy.is_empty() {
            return 1.0;
        }
        // uu columns: sqrt(wx_i wy_j) * (vx_i ∘ vy_j)
        let cols = ix.len() * iy.len();
        let mut uu = Mat::zeros(n, cols);
        let mut c = 0;
        for &i in &ix {
            for &j in &iy {
                let s = (wx[i] * wy[j]).sqrt();
                for r in 0..n {
                    uu[(r, c)] = s * vx[(r, i)] * vy[(r, j)];
                }
                c += 1;
            }
        }
        // uu_prod = uu uuᵀ; we only need tr(P) and tr(P²):
        // tr(P) = ‖uu‖_F²; tr(P²) = ‖uuᵀuu‖_F².
        let gram_small = uu.t_matmul(&uu); // cols×cols
        let mean = gram_small.trace();
        let var = 2.0 * gram_small.frob_dot(&gram_small);
        if mean <= 0.0 || var <= 0.0 {
            return 1.0;
        }
        let k_shape = mean * mean / var;
        let theta = var / mean;
        gamma_sf(sta, k_shape, theta).clamp(0.0, 1.0)
    }
}

impl CiTest for Kci {
    fn pvalue(&self, i: usize, j: usize, cond: &[usize]) -> f64 {
        let (a, b) = if i < j { (i, j) } else { (j, i) };
        let mut key_cond: Vec<usize> = cond.to_vec();
        key_cond.sort_unstable();
        let key = (a, b, key_cond.clone());
        if let Some(&p) = self.cache.lock().unwrap().get(&key) {
            return p;
        }
        *self.calls.lock().unwrap() += 1;
        let x = self.ds.block(a);
        let y = self.ds.block(b);
        let p = if key_cond.is_empty() {
            self.test_unconditional(&x, &y)
        } else {
            let z = self.ds.block_multi(&key_cond);
            self.test_conditional(&x, &y, &z)
        };
        self.cache.lock().unwrap().insert(key, p);
        p
    }

    fn num_vars(&self) -> usize {
        self.ds.d()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn tri_ds(n: usize, seed: u64) -> Arc<Dataset> {
        // X → Y → W chain plus independent V:
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 4);
        for r in 0..n {
            let x = rng.normal();
            let y = (1.5 * x).tanh() + 0.3 * rng.normal();
            let w = 1.2 * y + 0.3 * rng.normal();
            let v = rng.normal();
            data[(r, 0)] = x;
            data[(r, 1)] = y;
            data[(r, 2)] = w;
            data[(r, 3)] = v;
        }
        Arc::new(Dataset::from_columns(data, &[false; 4]))
    }

    #[test]
    fn detects_marginal_dependence() {
        let kci = Kci::new(tri_ds(200, 1));
        assert!(kci.pvalue(0, 1, &[]) < 0.01, "X,Y strongly dependent");
        assert!(kci.pvalue(0, 2, &[]) < 0.05, "X,W dependent through Y");
    }

    #[test]
    fn accepts_marginal_independence() {
        let kci = Kci::new(tri_ds(200, 2));
        let p = kci.pvalue(0, 3, &[]);
        assert!(p > 0.05, "independent pair should not be rejected: p={p}");
    }

    #[test]
    fn conditional_independence_given_mediator() {
        let kci = Kci::new(tri_ds(300, 3));
        let p_cond = kci.pvalue(0, 2, &[1]);
        assert!(p_cond > 0.05, "X ⊥ W | Y must hold: p={p_cond}");
        let p_dep = kci.pvalue(0, 1, &[3]);
        assert!(p_dep < 0.05, "X,Y dependent given irrelevant V: p={p_dep}");
    }

    #[test]
    fn unconditional_null_calibration() {
        // p-values under independence should not be concentrated near 0
        let mut rejections = 0;
        for seed in 0..20 {
            let mut rng = Pcg64::new(1000 + seed);
            let n = 100;
            let mut data = Mat::zeros(n, 2);
            for v in &mut data.data {
                *v = rng.normal();
            }
            let ds = Arc::new(Dataset::from_columns(data, &[false, false]));
            let kci = Kci::new(ds);
            if kci.pvalue(0, 1, &[]) < 0.05 {
                rejections += 1;
            }
        }
        assert!(rejections <= 4, "type-I error too high: {rejections}/20");
    }

    #[test]
    fn cache_symmetric_in_arguments() {
        let kci = Kci::new(tri_ds(100, 4));
        let p1 = kci.pvalue(0, 1, &[2]);
        let p2 = kci.pvalue(1, 0, &[2]);
        assert_eq!(p1, p2);
        assert_eq!(kci.calls(), 1, "second call must hit the cache");
    }
}
