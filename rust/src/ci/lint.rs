//! `cvlr lint` — repo-invariant checks that `cargo build` cannot
//! express, run in CI before the test matrix (`cargo run -- lint`).
//!
//! Four rules, each a pure function over file *contents* so every rule
//! is unit-testable against synthetic violations without touching the
//! filesystem:
//!
//! 1. **SAFETY comments** — every `unsafe` keyword in non-test code
//!    carries a `// SAFETY:` comment on the same line or within the
//!    few lines above it (shared comments cover adjacent `unsafe fn`s
//!    of one impl via the block rule below).
//! 2. **No unwrap on locks/I/O in the serving stack** — non-test code
//!    under `server/` and `distrib/` must not `.unwrap()`/`.expect()`
//!    a lock guard (`.lock()`, `.read()`, `.write()`) or a flush;
//!    locks go through `util::lockorder` (poison-absorbing, and the
//!    lock-order CI build checks acquisition cycles), I/O errors
//!    propagate with `?` + context.
//! 3. **Failpoints documented** — every site in `obs::fail::SITES`
//!    appears in README's "Failure semantics" section, so the chaos
//!    surface and its docs cannot drift apart.
//! 4. **Metrics declared** — every `cvlr_*` string literal in `obs/`
//!    and `server/mod.rs` matches an entry of
//!    [`crate::obs::metrics::DECLARED_METRICS`] exactly, or starts
//!    with an entry that ends in `_` (a declared dynamic-suffix
//!    family such as `cvlr_jobs_<state>`).
//!
//! Line/byte heuristics, not a parser: rules skip `#[cfg(test)]` mod
//! regions by brace tracking and comment-only lines where relevant.
//! That is deliberate — the lint must stay dependency-free and fast,
//! and a false positive is fixed by writing the comment the rule asks
//! for anyway.

use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::obs::fail;
use crate::obs::metrics::DECLARED_METRICS;

/// One rule violation, formatted `path:line: [rule] message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub path: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// How many lines above an `unsafe` keyword a `// SAFETY:` comment may
/// sit. Generous enough for an attribute + signature between the
/// comment and the keyword.
const SAFETY_LOOKBACK: usize = 6;

/// The keyword and tag, assembled so this file's own non-test code
/// never contains the keyword as a bare word (the lint lints itself).
const UNSAFE_KW: &str = concat!("un", "safe");
const UNSAFE_FN: &str = concat!("un", "safe fn");
const SAFETY_TAG: &str = "// SAFETY:";

fn is_word(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offsets of word-boundary occurrences of `word` in `line`.
fn word_positions(line: &str, word: &str) -> Vec<usize> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(i) = line[from..].find(word) {
        let at = from + i;
        let before_ok = at == 0 || !is_word(bytes[at - 1]);
        let after = at + word.len();
        let after_ok = after >= bytes.len() || !is_word(bytes[after]);
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len();
    }
    out
}

/// Line numbers (1-based) inside `#[cfg(test)] mod { … }` regions,
/// located by brace tracking from the `cfg` attribute's following
/// `mod`. Also covers `#[cfg(all(test, …))]`.
fn test_region_lines(content: &str) -> Vec<bool> {
    let lines: Vec<&str> = content.lines().collect();
    let mut in_test = vec![false; lines.len() + 1];
    let mut i = 0;
    while i < lines.len() {
        let t = lines[i].trim_start();
        let is_test_cfg = t.starts_with("#[cfg(")
            && t.contains("test")
            && !t.contains("not(test)");
        if !is_test_cfg {
            i += 1;
            continue;
        }
        // find the opening brace of the annotated item, then its close
        let mut depth = 0i64;
        let mut opened = false;
        let mut j = i;
        while j < lines.len() {
            in_test[j + 1] = true;
            for b in lines[j].bytes() {
                match b {
                    b'{' => {
                        depth += 1;
                        opened = true;
                    }
                    b'}' => depth -= 1,
                    _ => {}
                }
            }
            if opened && depth <= 0 {
                break;
            }
            j += 1;
        }
        i = j + 1;
    }
    in_test
}

/// Rule 1: every word-boundary `unsafe` in non-test, non-comment code
/// has a `// SAFETY:` comment nearby (same line or within
/// [`SAFETY_LOOKBACK`] lines above).
pub fn check_safety_comments(path: &str, content: &str) -> Vec<Violation> {
    let lines: Vec<&str> = content.lines().collect();
    let in_test = test_region_lines(content);
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let n = idx + 1;
        if in_test[n] {
            continue;
        }
        let trimmed = line.trim_start();
        if trimmed.starts_with("//") {
            continue; // comment or doc line mentioning the word
        }
        // ignore occurrences inside a trailing line comment, and
        // `unsafe fn` signatures: declaring one performs no unsafe
        // operation — `#![deny(unsafe_op_in_unsafe_fn)]` forces the
        // body's operations into blocks this rule does cover
        let code = line.split("//").next().unwrap_or(line).replace(UNSAFE_FN, "");
        if word_positions(&code, UNSAFE_KW).is_empty() {
            continue;
        }
        let covered = (idx.saturating_sub(SAFETY_LOOKBACK)..=idx)
            .any(|k| lines[k].contains(SAFETY_TAG));
        if !covered {
            out.push(Violation {
                path: path.to_string(),
                line: n,
                rule: "safety-comment",
                message: format!("`{UNSAFE_KW}` without a nearby `{SAFETY_TAG}` comment"),
            });
        }
    }
    out
}

/// Forbidden call chains for rule 2, matched on whitespace-condensed
/// text so multi-line method chains cannot hide one.
const LOCK_UNWRAP_PATTERNS: &[&str] = &[
    ".lock().unwrap()",
    ".lock().expect(",
    ".read().unwrap()",
    ".read().expect(",
    ".write().unwrap()",
    ".write().expect(",
    ".flush().unwrap()",
    ".flush().expect(",
];

/// Rule 2: no `.unwrap()`/`.expect()` on lock guards or flushes in
/// non-test serving-stack code. `path` decides applicability; the
/// caller passes every file, the rule self-selects.
pub fn check_lock_unwrap(path: &str, content: &str) -> Vec<Violation> {
    let normalized = path.replace('\\', "/");
    if !(normalized.contains("server/") || normalized.contains("distrib/")) {
        return Vec::new();
    }
    let in_test = test_region_lines(content);
    // condense: drop whitespace, remember each kept byte's line
    let mut condensed = String::new();
    let mut line_of = Vec::new();
    for (idx, line) in content.lines().enumerate() {
        if in_test[idx + 1] {
            continue;
        }
        let code = line.split("//").next().unwrap_or(line);
        for c in code.chars().filter(|c| !c.is_whitespace()) {
            condensed.push(c);
            line_of.push(idx + 1);
        }
    }
    let mut out = Vec::new();
    for pat in LOCK_UNWRAP_PATTERNS {
        let mut from = 0;
        while let Some(i) = condensed[from..].find(pat) {
            let at = from + i;
            out.push(Violation {
                path: path.to_string(),
                line: line_of[at],
                rule: "lock-unwrap",
                message: format!(
                    "`{pat}` in serving-stack code: use util::lockorder (locks) or propagate with `?` (I/O)"
                ),
            });
            from = at + pat.len();
        }
    }
    out.sort_by_key(|v| v.line);
    out
}

/// Rule 3: every failpoint site appears in README's
/// "## Failure semantics" section.
pub fn check_failpoints_documented(readme: &str, sites: &[&str]) -> Vec<Violation> {
    let section = match readme.find("## Failure semantics") {
        Some(start) => {
            let rest = &readme[start..];
            match rest[2..].find("\n## ") {
                Some(end) => &rest[..end + 2],
                None => rest,
            }
        }
        None => {
            return vec![Violation {
                path: "README.md".to_string(),
                line: 1,
                rule: "failpoint-docs",
                message: "README has no `## Failure semantics` section".to_string(),
            }]
        }
    };
    sites
        .iter()
        .filter(|site| !section.contains(*site))
        .map(|site| Violation {
            path: "README.md".to_string(),
            line: 1,
            rule: "failpoint-docs",
            message: format!(
                "failpoint site `{site}` (obs::fail::SITES) missing from the Failure semantics section"
            ),
        })
        .collect()
}

/// Extract every `"cvlr_…` string-literal prefix in non-test code:
/// the `cvlr_` start plus its maximal `[a-z0-9_]` run (a following
/// `{` or `"` ends the name — format strings contribute their static
/// prefix).
fn cvlr_literals(content: &str) -> Vec<(usize, String)> {
    let in_test = test_region_lines(content);
    let mut out = Vec::new();
    for (idx, line) in content.lines().enumerate() {
        if in_test[idx + 1] {
            continue;
        }
        let mut from = 0;
        while let Some(i) = line[from..].find("\"cvlr_") {
            let at = from + i + 1; // past the quote
            let name: String = line[at..]
                .chars()
                .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
                .collect();
            out.push((idx + 1, name));
            from = at + name.len();
        }
    }
    out
}

/// Rule 4: every `cvlr_*` literal matches `DECLARED_METRICS` (exactly,
/// or by a declared `…_` prefix family).
pub fn check_metrics_declared(path: &str, content: &str, declared: &[&str]) -> Vec<Violation> {
    cvlr_literals(content)
        .into_iter()
        .filter(|(_, name)| {
            !declared
                .iter()
                .any(|d| name == d || (d.ends_with('_') && name.starts_with(d)))
        })
        .map(|(line, name)| Violation {
            path: path.to_string(),
            line,
            rule: "metric-declared",
            message: format!(
                "metric literal `{name}` is not in obs::metrics::DECLARED_METRICS"
            ),
        })
        .collect()
}

/// Recursively collect `.rs` files under `dir`, sorted for
/// deterministic output.
fn rust_files(dir: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![dir.to_path_buf()];
    while let Some(d) = stack.pop() {
        for entry in
            fs::read_dir(&d).with_context(|| format!("reading {}", d.display()))?
        {
            let p = entry?.path();
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Run every rule over the tree rooted at the crate's own sources
/// (located from `CARGO_MANIFEST_DIR`, so `cargo run -- lint` works
/// from any cwd). Returns all violations, sorted.
pub fn run() -> Result<Vec<Violation>> {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let src = manifest.join("src");
    let readme = manifest
        .parent()
        .map(|repo| repo.join("README.md"))
        .filter(|p| p.is_file())
        .context("README.md not found next to the rust/ crate")?;

    let mut out = Vec::new();
    for file in rust_files(&src)? {
        let rel = file
            .strip_prefix(manifest)
            .unwrap_or(&file)
            .to_string_lossy()
            .replace('\\', "/");
        let content =
            fs::read_to_string(&file).with_context(|| format!("reading {}", file.display()))?;
        out.extend(check_safety_comments(&rel, &content));
        out.extend(check_lock_unwrap(&rel, &content));
        if rel.starts_with("src/obs/") || rel == "src/server/mod.rs" {
            out.extend(check_metrics_declared(&rel, &content, DECLARED_METRICS));
        }
    }
    let readme_text = fs::read_to_string(&readme)
        .with_context(|| format!("reading {}", readme.display()))?;
    out.extend(check_failpoints_documented(&readme_text, fail::SITES));
    out.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(out)
}

/// CLI entry: print violations and error out if any (`cvlr lint`).
pub fn run_cli() -> Result<()> {
    let violations = run()?;
    if violations.is_empty() {
        println!("cvlr lint: clean");
        return Ok(());
    }
    for v in &violations {
        eprintln!("{v}");
    }
    bail!("cvlr lint: {} violation(s)", violations.len());
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- rule 1: SAFETY comments ----------------------------------

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = "pub fn f(p: *mut u8) {\n    unsafe { p.write(0) };\n}\n";
        let v = check_safety_comments("src/x.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "safety-comment");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn unsafe_with_safety_comment_passes() {
        let src = "pub fn f(p: *mut u8) {\n    // SAFETY: p is valid per the caller contract\n    unsafe { p.write(0) };\n}\n";
        assert!(check_safety_comments("src/x.rs", src).is_empty());
    }

    #[test]
    fn safety_rule_skips_comments_tests_and_identifiers() {
        // the word in comments, in test code, and as part of an
        // identifier (`unsafe_op_in_unsafe_fn`) must not trip the rule
        let src = "\
// unsafe is discussed here\n\
#![deny(unsafe_op_in_unsafe_fn)]\n\
fn ok() {} // unsafe in a trailing comment\n\
#[cfg(test)]\n\
mod tests {\n\
    #[test]\n\
    fn t() {\n\
        let _ = unsafe { std::mem::transmute::<u32, i32>(0) };\n\
    }\n\
}\n";
        assert!(check_safety_comments("src/x.rs", src).is_empty());
    }

    // ---- rule 2: lock/I-O unwraps ---------------------------------

    #[test]
    fn lock_unwrap_in_serving_code_is_flagged() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
        let v = check_lock_unwrap("src/server/thing.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].rule, "lock-unwrap");
        assert_eq!(v[0].line, 2);
    }

    #[test]
    fn multiline_lock_unwrap_is_still_caught() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m\n        .lock()\n        .unwrap()\n}\n";
        let v = check_lock_unwrap("src/distrib/thing.rs", src);
        assert_eq!(v.len(), 1, "whitespace between chain links must not hide the pattern");
        assert_eq!(v[0].line, 2, "reported at the start of the chain");
    }

    #[test]
    fn lock_unwrap_outside_serving_scope_or_in_tests_passes() {
        let src = "fn f(m: &std::sync::Mutex<u32>) -> u32 {\n    *m.lock().unwrap()\n}\n";
        assert!(check_lock_unwrap("src/score/thing.rs", src).is_empty(), "scope is server/+distrib/");
        let test_only = "#[cfg(test)]\nmod tests {\n    fn f(m: &std::sync::Mutex<u32>) -> u32 {\n        *m.lock().unwrap()\n    }\n}\n";
        assert!(check_lock_unwrap("src/server/thing.rs", test_only).is_empty());
    }

    #[test]
    fn expect_on_locks_is_also_flagged() {
        let src = "fn f(m: &std::sync::RwLock<u32>) -> u32 {\n    *m.read().expect(\"poisoned\")\n}\n";
        assert_eq!(check_lock_unwrap("src/server/thing.rs", src).len(), 1);
    }

    // ---- rule 3: failpoint docs -----------------------------------

    #[test]
    fn undocumented_failpoint_site_is_flagged() {
        let readme = "# x\n\n## Failure semantics\n\nSites: `a.b`.\n\n## Next\n";
        let v = check_failpoints_documented(readme, &["a.b", "c.d"]);
        assert_eq!(v.len(), 1);
        assert!(v[0].message.contains("c.d"));
        assert!(check_failpoints_documented(readme, &["a.b"]).is_empty());
    }

    #[test]
    fn site_mentioned_outside_the_section_does_not_count() {
        let readme = "# x\n`c.d` is mentioned here.\n\n## Failure semantics\n\nSites: `a.b`.\n";
        let v = check_failpoints_documented(readme, &["c.d"]);
        assert_eq!(v.len(), 1, "the site must be documented in the section itself");
    }

    // ---- rule 4: declared metrics ---------------------------------

    #[test]
    fn undeclared_metric_literal_is_flagged() {
        let src = "fn f() {\n    super::counter(\"cvlr_surprise_total\", \"?\");\n}\n";
        let v = check_metrics_declared("src/obs/x.rs", src, &["cvlr_requests_total"]);
        assert_eq!(v.len(), 1);
        assert!(v[0].message.contains("cvlr_surprise_total"));
    }

    #[test]
    fn declared_exact_and_prefix_families_pass() {
        let src = "fn f() {\n    g(\"cvlr_requests_total\");\n    g(&format!(\"cvlr_jobs_{}\", s));\n}\n";
        let declared = &["cvlr_requests_total", "cvlr_jobs_"];
        assert!(check_metrics_declared("src/obs/x.rs", src, declared).is_empty());
    }

    #[test]
    fn prefix_families_require_the_trailing_underscore() {
        // `cvlr_requests_total` must not authorize `cvlr_requests_totals`
        let src = "fn f() { g(\"cvlr_requests_totals\"); }\n";
        let v = check_metrics_declared("src/obs/x.rs", src, &["cvlr_requests_total"]);
        assert_eq!(v.len(), 1);
    }

    // ---- the real tree --------------------------------------------

    #[test]
    fn repo_tree_is_lint_clean() {
        let violations = run().expect("lint walks the tree");
        assert!(
            violations.is_empty(),
            "lint violations in the tree:\n{}",
            violations.iter().map(|v| format!("  {v}\n")).collect::<String>()
        );
    }
}
