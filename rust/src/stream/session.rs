//! The streaming discovery session: an appendable dataset, per-variable
//! incremental factor states, targeted score-cache invalidation, and
//! GES warm-started from the previous equivalence class.
//!
//! Division of labor:
//!
//! * [`StreamBackend`] — a batch-aware CV-LR [`ScoreBackend`] whose
//!   factors live in incremental [`FactorState`]s instead of being
//!   re-derived per batch. Appending a chunk of `c` rows costs
//!   **O(c·m²)** factor work per tracked variable set (forward
//!   substitutions against the retained pivot factors) — never an
//!   O(n·m²) refactorize unless the residual tracker fires a re-pivot.
//! * [`StreamingDiscovery`] — the session façade: owns the backend and
//!   its memoizing `ScoreService`, invalidates the score cache after
//!   every append (every cached score depends on every row, so append
//!   invalidation is total — the counter is reported through
//!   `ServiceStats::invalidations`), and re-runs GES **warm-started**
//!   from the previous CPDAG via `SearchMethod::run_from`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{Context, Result};

use crate::coordinator::{EngineKind, ScoreService, ServiceStats};
use crate::data::Dataset;
use crate::graph::Pdag;
use crate::kernel::{gram, median_heuristic, Kernel};
use crate::linalg::Mat;
use crate::lowrank::LowRankConfig;
use crate::runtime::pjrt_kernel::PjrtCvLrKernel;
use crate::runtime::Runtime;
use crate::score::cores::{FoldCoreCache, PairCoreCache};
use crate::score::cvlr::{score_segment_with, CvLrKernel, NativeCvLrKernel};
use crate::score::folds::{stride_folds, CvParams};
use crate::score::{ScoreBackend, ScoreRequest};
use crate::search::ges::GesConfig;
use crate::search::{GesSearch, SearchMethod};
use crate::util::Stopwatch;

use super::append::FactorState;

/// Per-chunk append report.
#[derive(Clone, Copy, Debug, Default)]
pub struct AppendStats {
    /// Rows appended to the dataset.
    pub rows: usize,
    /// Factor states updated incrementally.
    pub states: usize,
    /// Discrete bases that grew new distinct-row pivots.
    pub basis_grown: usize,
    /// Full re-pivots forced by the residual tracker.
    pub repivots: usize,
    /// Score-cache entries invalidated (session only; 0 at the raw
    /// backend level).
    pub invalidated: u64,
    /// Wall-clock seconds of the factor maintenance.
    pub seconds: f64,
}

/// Result of one (possibly warm-started) discovery pass of the session.
#[derive(Clone)]
pub struct StreamOutcome {
    pub cpdag: Pdag,
    pub seconds: f64,
    /// Whether the search started from a previous CPDAG.
    pub warm_started: bool,
    pub forward_steps: usize,
    pub backward_steps: usize,
    pub batches: usize,
    /// Score requests issued by this pass alone (counter delta).
    pub requests: u64,
    /// How many of those were served from the memo cache.
    pub cache_hits: u64,
    /// Fresh backend evaluations this pass triggered.
    pub evaluations: u64,
}

/// Session configuration (paper defaults everywhere).
#[derive(Clone, Debug)]
pub struct StreamConfig {
    pub params: CvParams,
    pub lowrank: LowRankConfig,
    pub ges: GesConfig,
    /// Worker threads for the score service.
    pub workers: usize,
    /// Score-cache bound (None = unbounded).
    pub cache_capacity: Option<usize>,
    /// Gram-product threads for the fold-core builds
    /// (`DiscoveryConfig::parallelism` twin).
    pub parallelism: usize,
    /// CV-LR fold kernel: `Native` (pure rust, infallible) or `Pjrt`
    /// (the AOT-compiled XLA artifacts — loading can fail, so PJRT
    /// sessions go through [`StreamingDiscovery::try_with_config`]).
    pub engine: EngineKind,
    /// Artifacts directory for the PJRT engine.
    pub artifacts_dir: String,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            params: CvParams::default(),
            lowrank: LowRankConfig::default(),
            ges: GesConfig::default(),
            workers: 1,
            cache_capacity: None,
            parallelism: 1,
            engine: EngineKind::Native,
            artifacts_dir: "artifacts".to_string(),
        }
    }
}

/// Batch-aware CV-LR backend over an appendable dataset; factors are
/// maintained incrementally by [`FactorState`]s keyed by variable set.
///
/// Kernels are pinned per state at first use (median heuristic over the
/// rows present at that moment) — appends extend the factorization in
/// the same RKHS, and a re-pivot repairs approximation error without
/// re-tuning the width. Rebuild the backend to re-tune.
pub struct StreamBackend {
    data: RwLock<Dataset>,
    params: CvParams,
    lr_cfg: LowRankConfig,
    /// The fold kernel consuming the assembled core views — native by
    /// default, swappable for the PJRT artifact path
    /// ([`StreamBackend::with_kernel`]); the incremental factor
    /// machinery above it is engine-agnostic.
    kernel: Box<dyn CvLrKernel>,
    /// Gram-product threads for the fold-core builds.
    parallelism: usize,
    states: Mutex<HashMap<Vec<usize>, FactorState>>,
    /// Downdated per-(set, fold) self-cores over the live factor
    /// states; cleared wholesale on every append (every core depends on
    /// every row), rebuilt lazily from the incrementally maintained
    /// factors on the next score.
    cores: FoldCoreCache,
    /// Centered E/U cross-cores per (target, parents) pair — shared
    /// across segments and sweeps, cleared on every append with the
    /// self-cores.
    pairs: PairCoreCache,
}

impl StreamBackend {
    pub fn new(initial: Dataset, params: CvParams, lr_cfg: LowRankConfig) -> StreamBackend {
        StreamBackend {
            data: RwLock::new(initial),
            params,
            lr_cfg,
            kernel: Box::new(NativeCvLrKernel),
            parallelism: 1,
            states: Mutex::new(HashMap::new()),
            cores: FoldCoreCache::new(),
            pairs: PairCoreCache::new(),
        }
    }

    /// Swap the fold kernel (e.g. `PjrtCvLrKernel` for the AOT-compiled
    /// XLA path). Scores from any conforming kernel flow through the
    /// identical provider/cache machinery.
    pub fn with_kernel(mut self, kernel: Box<dyn CvLrKernel>) -> StreamBackend {
        self.kernel = kernel;
        self
    }

    /// Gram-product threads for the fold-core builds (default 1; `0` =
    /// auto — available cores capped at the fold count).
    pub fn with_parallelism(mut self, threads: usize) -> StreamBackend {
        self.parallelism = crate::score::cores::resolve_parallelism(threads, self.params.folds);
        self
    }

    /// The resolved Gram-product thread count.
    pub fn parallelism(&self) -> usize {
        self.parallelism
    }

    /// Bound the fold-core and pair-core caches (see
    /// `FoldCoreCache::with_capacity`); sessions default this from
    /// their score-cache capacity.
    pub fn with_core_capacity(mut self, capacity: Option<usize>) -> StreamBackend {
        self.cores = FoldCoreCache::with_capacity(capacity);
        self.pairs = PairCoreCache::with_capacity(capacity);
        self
    }

    /// Current number of samples.
    pub fn n(&self) -> usize {
        self.data.read().unwrap().n()
    }

    /// Snapshot of the current dataset (clones the sample matrix).
    pub fn dataset(&self) -> Dataset {
        self.data.read().unwrap().clone()
    }

    /// Current dataset row version.
    pub fn version(&self) -> u64 {
        self.data.read().unwrap().version()
    }

    /// Variable sets with live factor states.
    pub fn tracked_sets(&self) -> usize {
        self.states.lock().unwrap().len()
    }

    /// Append validated rows: O(c·m²) incremental factor work per
    /// tracked set (plus O(n·m) per *new* discrete level and a full
    /// re-pivot only when the residual budget is exhausted — both
    /// reported in the returned stats).
    pub fn append(&self, rows: &Mat) -> Result<AppendStats> {
        // chaos site: fails the append before any state mutates, so an
        // injected fault can never leave factors and data out of sync
        // (Delay/Panic run inline, Error and Corrupt both mean Err)
        if crate::obs::fail::hit("stream.append").is_some() {
            return Err(crate::obs::fail::injected_error("stream.append"));
        }
        let span = crate::obs::trace::span("stream-append", "stream")
            .arg("rows", rows.rows.to_string());
        let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::StreamAppend);
        let sw = Stopwatch::start();
        let mut ds = self.data.write().unwrap();
        let added = ds.append_rows(rows)?;
        let mut stats = AppendStats { rows: added, ..Default::default() };
        let mut states = self.states.lock().unwrap();
        stats.states = states.len();
        for (set, state) in states.iter_mut() {
            let chunk = ds.rows_block_multi(rows, set);
            let out = state.append(&chunk, &|| ds.block_multi(set));
            stats.basis_grown += out.basis_grown;
            stats.repivots += out.repivoted as usize;
        }
        // every fold core depends on every row: drop them all while the
        // data write lock still excludes concurrent scorers
        self.cores.clear();
        self.pairs.clear();
        stats.seconds = sw.secs();
        crate::obs::metrics::stream_append_seconds()
            .observe_with_exemplar(stats.seconds, span.id());
        Ok(stats)
    }

    /// Total re-pivots across all factor states.
    pub fn total_repivots(&self) -> u64 {
        self.states.lock().unwrap().values().map(|s| s.repivots()).sum()
    }

    /// Residual trace bound (base + appended mass) summed over the live
    /// factor states — how far the incremental bases have drifted since
    /// their last re-pivot.
    pub fn total_residual(&self) -> f64 {
        self.states.lock().unwrap().values().map(|s| s.residual()).sum()
    }

    /// Max |ΛΛᵀ − K|∞ across tracked factor states, evaluated against
    /// the current (post-append) data with each state's pinned kernel —
    /// the streaming exactness observable. O(n²) per state: diagnostics
    /// and tests only, never the hot path.
    pub fn max_reconstruction_error(&self) -> f64 {
        let ds = self.data.read().unwrap();
        let states = self.states.lock().unwrap();
        let mut worst = 0.0f64;
        for (set, st) in states.iter() {
            let block = ds.block_multi(set);
            let k = gram(st.kernel(), &block);
            let lam = st.lambda();
            worst = worst.max((&lam.matmul_t(&lam) - &k).max_abs());
        }
        worst
    }

    /// Factor for a variable set: the live incremental state, created
    /// over the current rows on first use (kernel width pinned then).
    fn factor_for(&self, vars: &[usize], ds: &Dataset) -> Arc<Mat> {
        let mut key = vars.to_vec();
        key.sort_unstable();
        if let Some(st) = self.states.lock().unwrap().get(&key) {
            return st.lambda();
        }
        // factorize OUTSIDE the states lock — the O(n·m²) build must
        // not serialize the score-service worker pool. Racing builders
        // of the same set: first insert wins, so appends always see one
        // canonical state (the loser's identical factor is still a
        // valid read for its own segment).
        let block = ds.block_multi(&key);
        let kern = Kernel::Rbf { sigma: median_heuristic(&block, self.params.width_factor) };
        let st = FactorState::new(kern, &block, ds.all_discrete(&key), &self.lr_cfg);
        self.states.lock().unwrap().entry(key).or_insert(st).lambda()
    }
}

impl ScoreBackend for StreamBackend {
    /// Same segmenting discipline as `CvLrScore::score_batch`: bounded
    /// transient cross-core storage, bit-identical to per-request
    /// scoring. Self-cores come from the fold-core cache (rebuilt from
    /// the incremental factor states after each append invalidates it).
    fn score_batch(&self, reqs: &[ScoreRequest]) -> Vec<f64> {
        const SEGMENT: usize = 64;
        let ds = self.data.read().unwrap();
        let folds = stride_folds(ds.n(), self.params.folds);
        let mut out = Vec::with_capacity(reqs.len());
        for seg in reqs.chunks(SEGMENT) {
            out.extend(score_segment_with(
                &self.params,
                self.kernel.as_ref(),
                seg,
                &mut |set: &[usize]| {
                    self.cores.get_or_build(set, &folds, self.parallelism, &mut || {
                        self.factor_for(set, &ds)
                    })
                },
                &self.pairs,
                self.parallelism,
            ));
        }
        out
    }

    fn num_vars(&self) -> usize {
        self.data.read().unwrap().d()
    }

    fn core_cache_stats(&self) -> Option<(u64, u64)> {
        Some((
            self.cores.len() as u64 + self.pairs.len() as u64,
            self.cores.evictions() + self.pairs.evictions(),
        ))
    }

    /// Core caches plus the live incremental factor states (the
    /// streaming twin of `CvLrScore::core_cache_bytes`).
    fn core_cache_bytes(&self) -> Option<u64> {
        let states: u64 = self
            .states
            .lock()
            .unwrap()
            .iter()
            .map(|(k, st)| {
                st.resident_bytes() + (k.capacity() * std::mem::size_of::<usize>()) as u64
            })
            .sum();
        Some(self.cores.resident_bytes() + self.pairs.resident_bytes() + states)
    }

    fn stream_stats(&self) -> Option<(u64, f64)> {
        Some((self.total_repivots(), self.total_residual()))
    }
}

/// The streaming discovery session: append row chunks, re-discover
/// warm-started, observe cache reuse.
///
/// ```no_run
/// # use cvlr::stream::StreamingDiscovery;
/// # fn run(initial: cvlr::data::Dataset, chunk: cvlr::linalg::Mat) -> anyhow::Result<()> {
/// let mut sess = StreamingDiscovery::new(initial);
/// let first = sess.discover();           // cold run on the seed rows
/// sess.append(&chunk)?;                  // O(c·m²) factor maintenance
/// let next = sess.discover();            // warm-started from `first`
/// assert!(next.warm_started);
/// # Ok(())
/// # }
/// ```
pub struct StreamingDiscovery {
    backend: Arc<StreamBackend>,
    service: Arc<ScoreService>,
    ges: GesConfig,
    chunks: u64,
}

impl StreamingDiscovery {
    /// Session with paper-default configuration. The initial dataset
    /// must have at least `2 × folds` rows (the CV split needs them).
    pub fn new(initial: Dataset) -> StreamingDiscovery {
        StreamingDiscovery::with_config(initial, StreamConfig::default())
    }

    /// Infallible construction — requires the native engine (the PJRT
    /// artifact load can fail; use
    /// [`StreamingDiscovery::try_with_config`] for it).
    pub fn with_config(initial: Dataset, cfg: StreamConfig) -> StreamingDiscovery {
        assert!(
            matches!(cfg.engine, EngineKind::Native),
            "with_config is native-only; PJRT sessions go through try_with_config"
        );
        StreamingDiscovery::try_with_config(initial, cfg)
            .expect("native stream construction is infallible")
    }

    /// Session over either fold kernel: native, or the PJRT engine
    /// (loading the XLA artifacts named by `cfg.artifacts_dir` — the
    /// one fallible step). The incremental factor machinery, the core
    /// caches and the warm-start protocol are identical across engines;
    /// only the m×m core algebra moves.
    pub fn try_with_config(initial: Dataset, cfg: StreamConfig) -> Result<StreamingDiscovery> {
        let kernel: Box<dyn CvLrKernel> = match cfg.engine {
            EngineKind::Native => Box::new(NativeCvLrKernel),
            EngineKind::Pjrt => {
                let rt = Arc::new(
                    Runtime::load(&cfg.artifacts_dir)
                        .context("loading PJRT artifacts for the streaming CV-LR engine")?,
                );
                Box::new(PjrtCvLrKernel::new(rt))
            }
        };
        let backend = Arc::new(
            StreamBackend::new(initial, cfg.params, cfg.lowrank)
                .with_kernel(kernel)
                .with_parallelism(cfg.parallelism)
                // the fold-core bound rides the score-cache bound: both
                // exist for the same long-lived-process reason
                .with_core_capacity(cfg.cache_capacity),
        );
        let dyn_backend: Arc<dyn ScoreBackend> = backend.clone();
        let service = Arc::new(ScoreService::with_cache_capacity(
            dyn_backend,
            cfg.workers,
            cfg.cache_capacity,
        ));
        service.set_gram_threads(backend.parallelism() as u64);
        Ok(StreamingDiscovery { backend, service, ges: cfg.ges, chunks: 0 })
    }

    /// Current number of samples.
    pub fn n(&self) -> usize {
        self.backend.n()
    }

    /// Chunks appended so far.
    pub fn chunks(&self) -> u64 {
        self.chunks
    }

    /// The underlying streaming backend (factor-state observables).
    pub fn backend(&self) -> &Arc<StreamBackend> {
        &self.backend
    }

    /// The memoizing score service (stats, warm-start state).
    pub fn service(&self) -> &Arc<ScoreService> {
        &self.service
    }

    /// Service counters (includes `invalidations` / `warm_start_hits`).
    pub fn stats(&self) -> ServiceStats {
        self.service.stats()
    }

    /// Append a chunk: incremental factor maintenance plus score-cache
    /// invalidation (every cached score depends on every row).
    pub fn append(&mut self, rows: &Mat) -> Result<AppendStats> {
        let mut stats = self.backend.append(rows)?;
        stats.invalidated = self.service.invalidate_all();
        self.chunks += 1;
        Ok(stats)
    }

    /// Run discovery, warm-started from the previous pass's CPDAG when
    /// one exists (the first pass is cold).
    pub fn discover(&mut self) -> StreamOutcome {
        let before = self.service.stats();
        let sw = Stopwatch::start();
        let warm = self.service.warm_start();
        let res = GesSearch.run_from(&*self.service, &self.ges, warm.as_ref());
        self.service.set_warm_start(res.cpdag.clone());
        let after = self.service.stats();
        StreamOutcome {
            cpdag: res.cpdag,
            seconds: sw.secs(),
            warm_started: warm.is_some(),
            forward_steps: res.forward_steps,
            backward_steps: res.backward_steps,
            batches: res.batches,
            requests: after.requests - before.requests,
            cache_hits: after.cache_hits - before.cache_hits,
            evaluations: after.evaluations - before.evaluations,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    /// X1 → X2 chain plus an isolated X3, raw rows for chunk replay.
    fn chain_rows(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut data = Mat::zeros(n, 3);
        for r in 0..n {
            let x1 = rng.normal();
            let x2 = 1.3 * x1 + 0.4 * rng.normal();
            let x3 = rng.normal();
            data[(r, 0)] = x1;
            data[(r, 1)] = x2;
            data[(r, 2)] = x3;
        }
        data
    }

    #[test]
    fn session_appends_invalidate_and_warm_start() {
        let rows = chain_rows(180, 1);
        let head =
            Dataset::from_columns(rows.select_rows(&(0..120).collect::<Vec<_>>()), &[false; 3]);
        let mut sess = StreamingDiscovery::new(head);
        let first = sess.discover();
        assert!(!first.warm_started, "first pass is cold");
        assert!(first.evaluations > 0);

        let tail = rows.select_rows(&(120..180).collect::<Vec<_>>());
        let ast = sess.append(&tail).unwrap();
        assert_eq!(ast.rows, 60);
        assert!(ast.states > 0, "the first pass created factor states");
        assert!(ast.invalidated > 0, "cached scores must be invalidated");
        assert_eq!(sess.n(), 180);

        let second = sess.discover();
        assert!(second.warm_started, "second pass starts from the previous CPDAG");
        let st = sess.stats();
        assert!(st.invalidations > 0);
        assert_eq!(st.warm_start_hits, 1);
        assert!(st.consistent(), "{st:?}");
        // factors stayed honest across the append (the bound is the
        // factorization's own, not the stream's: a rank-capped ICL
        // state carries its cold-run residual too)
        assert!(sess.backend().max_reconstruction_error() < 1e-3);
    }

    /// An explicitly boxed kernel must flow through the identical
    /// provider/cache machinery as the default — same bits out.
    #[test]
    fn boxed_kernel_routing_is_bit_identical() {
        let ds = Dataset::from_columns(chain_rows(80, 4), &[false; 3]);
        let a = StreamBackend::new(ds.clone(), CvParams::default(), LowRankConfig::default());
        let b = StreamBackend::new(ds, CvParams::default(), LowRankConfig::default())
            .with_kernel(Box::new(NativeCvLrKernel));
        let reqs = [
            ScoreRequest::new(1, &[0]),
            ScoreRequest::new(2, &[0, 1]),
            ScoreRequest::new(0, &[]),
        ];
        assert_eq!(a.score_batch(&reqs), b.score_batch(&reqs));
    }

    #[test]
    fn appends_clear_pair_cores() {
        let ds = Dataset::from_columns(chain_rows(90, 5), &[false; 3]);
        let backend = StreamBackend::new(ds, CvParams::default(), LowRankConfig::default());
        let reqs = [ScoreRequest::new(1, &[0])];
        let _ = backend.score_batch(&reqs);
        let (entries, _) = backend.core_cache_stats().unwrap();
        assert!(entries >= 3, "self-cores for {{0}},{{1}} plus one pair: {entries}");
        backend.append(&chain_rows(10, 6)).unwrap();
        let (after, _) = backend.core_cache_stats().unwrap();
        assert_eq!(after, 0, "appends clear both core caches");
    }

    #[test]
    fn stream_stats_surface_repivots_and_residual() {
        let ds = Dataset::from_columns(chain_rows(90, 7), &[false; 3]);
        let backend = StreamBackend::new(ds, CvParams::default(), LowRankConfig::default());
        let _ = backend.score_batch(&[ScoreRequest::new(1, &[0])]);
        let (repivots, residual) = backend.stream_stats().expect("streaming backends report");
        assert_eq!(repivots, backend.total_repivots());
        assert!(residual >= 0.0, "residual is a trace bound: {residual}");
        assert!(residual.is_finite());
        // the service surfaces the same pair through its stats snapshot
        let svc = ScoreService::new(Arc::new(backend), 1);
        let st = svc.stats();
        assert_eq!(st.stream_repivots, repivots);
        assert!((st.stream_residual - residual).abs() < 1e-12);
    }

    #[test]
    fn backend_append_rejects_bad_rows() {
        let ds = Dataset::from_columns(chain_rows(60, 2), &[false; 3]);
        let backend = StreamBackend::new(ds, CvParams::default(), LowRankConfig::default());
        assert!(backend.append(&Mat::zeros(1, 2)).is_err(), "arity mismatch");
        let mut bad = Mat::zeros(1, 3);
        bad[(0, 1)] = f64::INFINITY;
        assert!(backend.append(&bad).is_err(), "non-finite row");
        assert_eq!(backend.n(), 60, "failed appends mutate nothing");
        assert_eq!(backend.version(), 0);
    }

    #[test]
    fn backend_scores_match_before_and_after_noop_state_creation() {
        // scoring after an append must agree with a fresh backend over
        // the same full data when the factors carry the same kernel:
        // exercised here on discrete data, where Algorithm 2 is exact
        // and the median-heuristic width is stable across the split
        let mut rng = Pcg64::new(3);
        let n = 120;
        let mut data = Mat::zeros(n, 2);
        for r in 0..n {
            let a = rng.below(3);
            let b = if rng.bernoulli(0.8) { a } else { rng.below(3) };
            data[(r, 0)] = a as f64;
            data[(r, 1)] = b as f64;
        }
        let full = Dataset::from_columns(data.clone(), &[true, true]);
        let head = full.head(80);
        let streamed = StreamBackend::new(head, CvParams::default(), LowRankConfig::default());
        // touch the factors, then append the tail
        let req = [ScoreRequest::new(1, &[0]), ScoreRequest::new(0, &[])];
        let _ = streamed.score_batch(&req);
        streamed.append(&data.select_rows(&(80..n).collect::<Vec<_>>())).unwrap();
        let got = streamed.score_batch(&req);

        let cold = StreamBackend::new(full, CvParams::default(), LowRankConfig::default());
        let want = cold.score_batch(&req);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                ((g - w) / w).abs() < 1e-9,
                "streamed {g} vs cold {w} must agree on discrete data"
            );
        }
        assert!(streamed.max_reconstruction_error() < 1e-9);
    }
}
