//! Streaming discovery: appendable datasets, incremental low-rank
//! factor updates, and warm-started search.
//!
//! The paper's O(n) scoring rests on low-rank factors whose structure
//! is inherently incremental: with the pivot set retained, a new sample
//! row folds into Λ with one O(m²) forward substitution ([`append`]),
//! so arriving data never forces the O(n·m²) from-scratch
//! factorization the batch pipeline would pay. On top of that,
//! [`session`] keeps discovery itself warm: appends invalidate exactly
//! the memoized scores they stale (counted in
//! `ServiceStats::invalidations`), and the next GES pass starts from
//! the previous CPDAG (`SearchMethod::run_from`) instead of the empty
//! graph.
//!
//! Entry points:
//!
//! * [`StreamingDiscovery`] — the session façade (`append` →
//!   `discover`, warm-started);
//! * [`StreamBackend`] — the appendable batch-aware CV-LR
//!   [`crate::score::ScoreBackend`] behind it;
//! * [`FactorState`] — one incrementally maintained factor (public for
//!   direct use and property tests).
//!
//! The CLI front end is `cvlr stream --data f.csv --chunk N`, which
//! replays a workload as a row stream and reports per-chunk append and
//! discovery latency; the server front end is
//! `POST /v1/datasets/{name}/rows` plus the `warm_start` job option.

pub mod append;
pub mod session;

pub use append::{AppendOutcome, FactorState};
pub use session::{AppendStats, StreamBackend, StreamConfig, StreamOutcome, StreamingDiscovery};
