//! Incremental low-rank factor maintenance — the streaming heart of
//! the subsystem.
//!
//! Both of the paper's factorizations are *structurally incremental*:
//! once the pivot set is fixed, every row of Λ is one forward
//! substitution of the kernel vector k(x, pivots) against the
//! lower-triangular pivot factor L —
//!
//! * **Algorithm 1 (ICL)**: the pivot rows of Λ form exactly that
//!   lower-triangular block (Bach & Jordan's recursion evaluates
//!   `λ_j[i] = (k(x_j, p_i) − Σ_{r<i} λ_j[r]·L[i,r]) / L[i,i]`), so a
//!   new sample folds into Λ in **O(m²)** without touching the n
//!   existing rows;
//! * **Algorithm 2 (discrete)**: Λ = K_{XX'} L⁻ᵀ with L the Cholesky
//!   factor of the distinct-row pivot kernel — the same forward
//!   substitution; a *new distinct value* extends L by one row (O(m²))
//!   and Λ by one column (O(n·m), paid at most `cardinality` times over
//!   the stream's lifetime).
//!
//! Exactness is tracked, never silently lost: each appended row
//! contributes its residual `d = k(x,x) − ‖λ‖²` to a running total, and
//! once the appended residual exceeds the η budget the state
//! **re-pivots** — a full refactorization over all rows with the same
//! (pinned) kernel, identical to what a cold factorization of the full
//! data would produce.
//!
//! **Random Fourier features** (`FactorMethod::Rff`) sidestep all of
//! the above: the feature map is a pure function of the pinned kernel
//! — no pivot rows, no pivot factor — so a new sample folds in with one
//! **O(m·dim)** feature evaluation that is *bit-for-bit* the row a cold
//! refactorization over the full data would produce. There is no
//! residual budget and no re-pivot path; the appended-residual counter
//! is still maintained (the |diagonal| Monte-Carlo residual) purely as
//! an observable.

use std::sync::Arc;

use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::lowrank::{
    discrete_decomposition_detailed, distinct_rows, icl_detailed, rff_factorize, FactorMethod,
    LowRankConfig, Method, RffMap,
};

/// What happened to one factor state during a chunk append.
#[derive(Clone, Copy, Debug, Default)]
pub struct AppendOutcome {
    /// Rows folded in incrementally (O(m²) each).
    pub appended: usize,
    /// New distinct-row pivots added to a discrete basis.
    pub basis_grown: usize,
    /// Whether the residual tracker (or a basis overflow) forced a full
    /// re-pivot over all rows.
    pub repivoted: bool,
}

/// A low-rank factor that can absorb new sample rows in O(m²) each.
///
/// The kernel is **pinned** at construction (widths chosen by the
/// median heuristic would drift as rows arrive, which would invalidate
/// the retained pivot algebra); a re-pivot repairs approximation error
/// in the same RKHS. Rebuild the state to re-tune the kernel.
pub struct FactorState {
    kernel: Kernel,
    /// Current n × m factor (Arc so score batches can borrow it without
    /// copying; appends use copy-on-write which is a no-op when no
    /// batch is holding a reference).
    lambda: Arc<Mat>,
    /// Pivot data rows (m × dim), in pivot order.
    xp: Mat,
    /// Lower-triangular pivot factor L (m × m): every row of Λ solves
    /// `L λ = k(x, pivots)`.
    lp: Mat,
    method: Method,
    is_discrete: bool,
    cfg: LowRankConfig,
    /// The data-independent feature map when the state is RFF-backed
    /// (`xp`/`lp` are then empty — there are no pivots to retain).
    rff: Option<RffMap>,
    /// Residual trace at (re-)factorization time.
    base_residual: f64,
    /// Residual mass contributed by rows appended since.
    appended_residual: f64,
    /// ICL stopped at the rank cap with residual ≥ η.
    capped: bool,
    repivots: u64,
}

/// Appended-residual slack for rank-capped ICL states, as a fraction of
/// the base residual. A capped factor sits above η by construction —
/// demanding η of the appended rows would re-pivot on every chunk
/// (O(n·m²) each, the exact cost streaming exists to avoid), and the
/// re-pivot cannot get back below η anyway. Allowing a fixed fraction
/// instead bounds the quality loss relative to what the factor already
/// has, and amortizes the re-pivot over Θ(n) rows (per-row residual of
/// in-distribution data scales like base/n), keeping appends O(m²)
/// amortized.
const CAPPED_REPIVOT_SLACK: f64 = 0.1;

/// Solve the lower-triangular system `L y = b` (one Λ row).
fn forward_solve(l: &Mat, b: &[f64]) -> Vec<f64> {
    let mut y = b.to_vec();
    for i in 0..y.len() {
        for k in 0..i {
            let t = l[(i, k)] * y[k];
            y[i] -= t;
        }
        y[i] /= l[(i, i)];
    }
    y
}

impl FactorState {
    /// Factorize `block` with the §7.1 dispatch (Algorithm 2 for
    /// discrete data with ≤ m₀ distinct rows, Algorithm 1 otherwise),
    /// retaining the pivot data and pivot factor for appends. Produces
    /// bit-identical factors to `lowrank::factorize` with the same
    /// kernel.
    pub fn new(kernel: Kernel, block: &Mat, is_discrete: bool, cfg: &LowRankConfig) -> FactorState {
        // This path factorizes directly (icl_detailed / rff_factorize)
        // rather than through `lowrank::factorize`, so it charges the
        // factorize memory scope itself.
        let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::Factorize);
        if is_discrete {
            let distinct = distinct_rows(block);
            if distinct.len() <= cfg.max_rank {
                if let Some((lambda, lp)) =
                    discrete_decomposition_detailed(kernel, block, &distinct)
                {
                    let xp = block.select_rows(&distinct);
                    return FactorState {
                        kernel,
                        lambda: Arc::new(lambda),
                        xp,
                        lp,
                        method: Method::Discrete,
                        is_discrete,
                        cfg: *cfg,
                        rff: None,
                        base_residual: 0.0,
                        appended_residual: 0.0,
                        capped: false,
                        repivots: 0,
                    };
                }
            }
        }
        if cfg.method == FactorMethod::Rff {
            // the one shared factorization routine (`rff_factorize`),
            // so the factor is bit-identical to `lowrank::factorize`
            if let Some((map, lambda, residual)) =
                rff_factorize(kernel, block, cfg.max_rank, cfg.rff_seed)
            {
                return FactorState {
                    kernel,
                    lambda: Arc::new(lambda),
                    xp: Mat::zeros(0, block.cols),
                    lp: Mat::zeros(0, 0),
                    method: Method::Rff,
                    is_discrete,
                    cfg: *cfg,
                    rff: Some(map),
                    base_residual: residual,
                    appended_residual: 0.0,
                    capped: false,
                    repivots: 0,
                };
            }
            // non-RBF kernel: fall through to ICL, like `factorize`
        }
        let f = icl_detailed(kernel, block, cfg.eta, cfg.max_rank);
        let m = f.pivots.len();
        let mut lp = Mat::zeros(m, m);
        for (i, &p) in f.pivots.iter().enumerate() {
            for c in 0..=i {
                lp[(i, c)] = f.lambda[(p, c)];
            }
        }
        FactorState {
            kernel,
            xp: block.select_rows(&f.pivots),
            lambda: Arc::new(f.lambda),
            lp,
            method: Method::Icl,
            is_discrete,
            cfg: *cfg,
            rff: None,
            base_residual: f.residual,
            appended_residual: 0.0,
            capped: f.capped,
            repivots: 0,
        }
    }

    /// The current factor (rows = all samples seen so far).
    pub fn lambda(&self) -> Arc<Mat> {
        self.lambda.clone()
    }

    /// Resident heap bytes of the state: the factor Λ plus the retained
    /// pivot data/factor (or the RFF frequency table). The O(n·m)
    /// factor dominates — the term that must stay linear in n for the
    /// streaming space claim.
    pub fn resident_bytes(&self) -> u64 {
        let rff = self.rff.as_ref().map_or(0, |m| {
            m.omega.resident_bytes() + (m.phases.capacity() * std::mem::size_of::<f64>()) as u64
        });
        self.lambda.resident_bytes() + self.xp.resident_bytes() + self.lp.resident_bytes() + rff
    }

    /// Number of pivots (columns of Λ).
    pub fn rank(&self) -> usize {
        self.lambda.cols
    }

    /// Which algorithm currently backs the factor.
    pub fn method(&self) -> Method {
        self.method
    }

    /// The pinned kernel.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// Full re-pivots performed so far.
    pub fn repivots(&self) -> u64 {
        self.repivots
    }

    /// Current residual trace bound: base + appended mass.
    pub fn residual(&self) -> f64 {
        self.base_residual + self.appended_residual
    }

    /// Appended-residual budget before a re-pivot fires. A converged
    /// factor may absorb up to `η − r₀` extra residual before total
    /// exactness degrades past η; a rank-capped ICL factor budgets a
    /// fraction of its own base residual instead (see
    /// [`CAPPED_REPIVOT_SLACK`]) — re-pivoting re-runs the greedy pivot
    /// selection over the new rows once drift accumulates, without
    /// degenerating to refactorize-per-chunk.
    fn repivot_threshold(&self) -> f64 {
        if self.capped {
            self.cfg.eta.max(CAPPED_REPIVOT_SLACK * self.base_residual)
        } else {
            (self.cfg.eta - self.base_residual).max(0.0)
        }
    }

    /// Fold `chunk` rows into Λ. `full` lazily materializes the *entire*
    /// post-append block (existing rows first, chunk rows last, same
    /// column layout) — it is only invoked on the rare paths that need
    /// all rows: discrete basis growth and re-pivot.
    pub fn append(&mut self, chunk: &Mat, full: &dyn Fn() -> Mat) -> AppendOutcome {
        let _mem = crate::obs::mem::MemScope::enter(crate::obs::mem::Scope::StreamAppend);
        let mut out = AppendOutcome::default();
        if self.method == Method::Rff {
            // exact-by-construction appends: each row is the same
            // O(m·dim) feature evaluation a cold refactorization would
            // perform, so there is no drift to track and no re-pivot
            // path — `full` is never invoked
            let map = self.rff.as_ref().expect("RFF state retains its feature map");
            let rows = map.features(chunk);
            let mut resid = 0.0;
            for r in 0..chunk.rows {
                resid += crate::lowrank::rff::row_residual(self.kernel, chunk.row(r), rows.row(r));
            }
            Arc::make_mut(&mut self.lambda).append_rows(&rows);
            // observability only: the Monte-Carlo |diagonal| residual
            // accumulates but never triggers a re-pivot
            self.appended_residual += resid;
            out.appended = chunk.rows;
            return out;
        }
        for r in 0..chunk.rows {
            let x: Vec<f64> = chunk.row(r).to_vec();
            if self.method == Method::Discrete && self.basis_index(&x).is_none() {
                let grown = self.xp.rows < self.cfg.max_rank && self.grow_basis(&x, &full());
                if grown {
                    out.basis_grown += 1;
                } else {
                    // basis overflowed the rank cap (or went singular):
                    // Algorithm 2 no longer applies — re-dispatch over
                    // the full data (which will pick ICL)
                    self.repivot(&full());
                    out.repivoted = true;
                    return out;
                }
            }
            let (row, resid) = self.solve_row(&x);
            let lam = Arc::make_mut(&mut self.lambda);
            let cols = lam.cols;
            lam.append_rows(&Mat::from_vec(1, cols, row));
            self.appended_residual += resid.max(0.0);
            out.appended += 1;
        }
        if self.appended_residual > self.repivot_threshold() {
            self.repivot(&full());
            out.repivoted = true;
        }
        out
    }

    /// λ row and residual `d = k(x,x) − ‖λ‖²` for one new sample.
    fn solve_row(&self, x: &[f64]) -> (Vec<f64>, f64) {
        let m = self.xp.rows;
        let mut kv = vec![0.0; m];
        for i in 0..m {
            kv[i] = self.kernel.eval(x, self.xp.row(i));
        }
        let lam = forward_solve(&self.lp, &kv);
        let resid = self.kernel.eval_diag(x) - lam.iter().map(|v| v * v).sum::<f64>();
        (lam, resid)
    }

    /// Index of `x` in the distinct-row basis, if present.
    fn basis_index(&self, x: &[f64]) -> Option<usize> {
        (0..self.xp.rows).find(|&i| self.xp.row(i) == x)
    }

    /// Extend the discrete basis with new distinct row `p`: one new row
    /// of L (O(m²)) and one new column of Λ (O(n·m), using the full
    /// data block for the kernel evaluations). Returns false if the
    /// extended pivot kernel is singular to precision (caller falls
    /// back to a re-pivot).
    fn grow_basis(&mut self, p: &[f64], full: &Mat) -> bool {
        let m = self.xp.rows;
        let mut kv = vec![0.0; m];
        for i in 0..m {
            kv[i] = self.kernel.eval(p, self.xp.row(i));
        }
        let l = forward_solve(&self.lp, &kv);
        // sequential subtraction, matching `Cholesky::new`'s operation
        // order bit for bit (a re-run of Algorithm 2 over the extended
        // basis must reproduce this factor exactly)
        let mut diag2 = self.kernel.eval_diag(p);
        for &lj in &l {
            diag2 -= lj * lj;
        }
        if diag2 <= 1e-12 {
            return false;
        }
        let lmm = diag2.sqrt();
        let mut lp2 = Mat::zeros(m + 1, m + 1);
        for i in 0..m {
            for j in 0..=i {
                lp2[(i, j)] = self.lp[(i, j)];
            }
        }
        for (j, &lj) in l.iter().enumerate() {
            lp2[(m, j)] = lj;
        }
        lp2[(m, m)] = lmm;

        let kernel = self.kernel;
        let lam = Arc::make_mut(&mut self.lambda);
        let n = lam.rows;
        let mut grown = Mat::zeros(n, m + 1);
        for i in 0..n {
            let row = lam.row(i);
            grown.row_mut(i)[..m].copy_from_slice(row);
            // sequential subtraction in pivot order — the same FP
            // sequence `Cholesky::forward_sub` produces on a cold run
            let mut v = kernel.eval(full.row(i), p);
            for (a, b) in row.iter().zip(&l) {
                v -= a * b;
            }
            grown[(i, m)] = v / lmm;
        }
        *lam = grown;
        self.lp = lp2;
        self.xp.append_rows(&Mat::from_vec(1, p.len(), p.to_vec()));
        true
    }

    /// Full refactorization over all rows with the pinned kernel —
    /// identical to a cold `FactorState::new` on the same block.
    fn repivot(&mut self, full: &Mat) {
        crate::obs::metrics::stream_repivots_total().inc();
        crate::obs::trace::instant(
            "re-pivot",
            "stream",
            vec![("residual".to_string(), format!("{:.3e}", self.appended_residual))],
        );
        let repivots = self.repivots + 1;
        *self = FactorState::new(self.kernel, full, self.is_discrete, &self.cfg);
        self.repivots = repivots;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{gram, median_heuristic};
    use crate::util::Pcg64;

    fn normals(n: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let mut m = Mat::zeros(n, cols);
        for v in &mut m.data {
            *v = rng.normal();
        }
        m
    }

    fn levels(n: usize, k: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        Mat::from_vec(n, 1, (0..n).map(|_| rng.below(k) as f64).collect())
    }

    fn head(m: &Mat, n: usize) -> Mat {
        m.select_rows(&(0..n).collect::<Vec<_>>())
    }

    fn tail(m: &Mat, from: usize) -> Mat {
        m.select_rows(&(from..m.rows).collect::<Vec<_>>())
    }

    #[test]
    fn matches_cold_factorize_at_construction() {
        let x = normals(50, 2, 1);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        let cfg = LowRankConfig::default();
        let st = FactorState::new(kern, &x, false, &cfg);
        let cold = crate::lowrank::factorize(kern, &x, false, &cfg);
        assert_eq!(st.lambda().data, cold.lambda.data, "bit-for-bit vs factorize");
        assert_eq!(st.method(), cold.method);
    }

    #[test]
    fn append_keeps_reconstruction_bounded_continuous() {
        let x = normals(70, 1, 2);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        let cfg = LowRankConfig::default();
        let mut st = FactorState::new(kern, &head(&x, 45), false, &cfg);
        let out = st.append(&tail(&x, 45), &|| x.clone());
        assert_eq!(st.lambda().rows, 70);
        let err = (&st.lambda().matmul_t(&st.lambda()) - &gram(kern, &x)).max_abs();
        assert!(err < 1e-4, "reconstruction error {err} (repivoted={})", out.repivoted);
    }

    #[test]
    fn low_rank_data_appends_without_repivot() {
        // 4 distinct values through the ICL path: appended duplicates
        // carry ~zero residual, so the incremental path never re-pivots
        let x = levels(80, 4, 3);
        let kern = Kernel::Rbf { sigma: 1.0 };
        let cfg = LowRankConfig::default();
        let mut st = FactorState::new(kern, &head(&x, 40), false, &cfg);
        assert_eq!(st.method(), Method::Icl);
        let out = st.append(&tail(&x, 40), &|| x.clone());
        assert!(!out.repivoted, "duplicate rows must not trigger a re-pivot");
        assert_eq!(out.appended, 40);
        let err = (&st.lambda().matmul_t(&st.lambda()) - &gram(kern, &x)).max_abs();
        assert!(err < 1e-6, "reconstruction error {err}");
    }

    #[test]
    fn discrete_append_is_exact_and_grows_basis() {
        // first 40 rows only see levels {0,1,2}; the tail introduces 3
        let mut x = levels(80, 3, 4);
        for r in 60..70 {
            x[(r, 0)] = 3.0;
        }
        let kern = Kernel::Rbf { sigma: 1.0 };
        let cfg = LowRankConfig::default();
        let base_distinct = distinct_rows(&head(&x, 40)).len();
        let full_distinct = distinct_rows(&x).len();
        let mut st = FactorState::new(kern, &head(&x, 40), true, &cfg);
        assert_eq!(st.method(), Method::Discrete);
        assert_eq!(st.rank(), base_distinct);
        let out = st.append(&tail(&x, 40), &|| x.clone());
        assert_eq!(
            out.basis_grown,
            full_distinct - base_distinct,
            "every new level must grow the basis exactly once"
        );
        assert!(out.basis_grown >= 1, "level 3 is new by construction");
        assert!(!out.repivoted);
        assert_eq!(st.rank(), full_distinct);
        let err = (&st.lambda().matmul_t(&st.lambda()) - &gram(kern, &x)).max_abs();
        assert!(err < 1e-9, "Algorithm 2 must stay exact across appends: {err}");
    }

    #[test]
    fn forced_repivot_equals_cold_factorization_bit_for_bit() {
        let x = normals(60, 2, 5);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        // η = 0 leaves no appended-residual budget: the first genuinely
        // novel row forces a re-pivot
        let cfg = LowRankConfig { max_rank: 60, eta: 0.0, ..Default::default() };
        let mut st = FactorState::new(kern, &head(&x, 40), false, &cfg);
        let out = st.append(&tail(&x, 40), &|| x.clone());
        assert!(out.repivoted, "zero budget must force a re-pivot");
        assert_eq!(st.repivots(), 1);
        let cold = FactorState::new(kern, &x, false, &cfg);
        assert_eq!(
            st.lambda().data,
            cold.lambda().data,
            "re-pivot must be bit-for-bit the cold factorization"
        );
    }

    #[test]
    fn rff_state_matches_cold_factorize_at_construction() {
        let x = normals(50, 2, 7);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        let cfg = LowRankConfig::with_method(FactorMethod::Rff);
        let st = FactorState::new(kern, &x, false, &cfg);
        assert_eq!(st.method(), Method::Rff);
        let cold = crate::lowrank::factorize(kern, &x, false, &cfg);
        assert_eq!(st.lambda().data, cold.lambda.data, "bit-for-bit vs factorize");
        assert_eq!(st.method(), cold.method);
    }

    #[test]
    fn rff_append_is_bit_for_bit_and_never_repivots() {
        let x = normals(90, 2, 8);
        let kern = Kernel::Rbf { sigma: median_heuristic(&x, 2.0) };
        // η = 0 (zero residual budget) would force an ICL state to
        // re-pivot on the first novel row; RFF has no budget at all
        let cfg = LowRankConfig { eta: 0.0, method: FactorMethod::Rff, ..Default::default() };
        let mut st = FactorState::new(kern, &head(&x, 40), false, &cfg);
        let panic_on_full: &dyn Fn() -> Mat =
            &|| panic!("RFF appends must never materialize the full block");
        let out1 = st.append(&x.select_rows(&(40..70).collect::<Vec<_>>()), panic_on_full);
        let out2 = st.append(&tail(&x, 70), panic_on_full);
        assert!(!out1.repivoted && !out2.repivoted);
        assert_eq!(out1.appended + out2.appended, 50);
        assert_eq!(st.repivots(), 0, "RFF has no re-pivot path");
        let cold = FactorState::new(kern, &x, false, &cfg);
        assert_eq!(
            st.lambda().data,
            cold.lambda().data,
            "data-independent features: append == cold refactorize bit-for-bit"
        );
        assert!(st.residual() > 0.0, "the Monte-Carlo residual observable accumulates");
    }

    #[test]
    fn chunked_append_matches_one_shot_append() {
        let x = levels(90, 5, 6);
        let kern = Kernel::Rbf { sigma: 1.0 };
        let cfg = LowRankConfig::default();
        let mut chunked = FactorState::new(kern, &head(&x, 30), true, &cfg);
        let mid = x.select_rows(&(30..60).collect::<Vec<_>>());
        let part = head(&x, 60);
        chunked.append(&mid, &|| part.clone());
        chunked.append(&tail(&x, 60), &|| x.clone());
        let mut oneshot = FactorState::new(kern, &head(&x, 30), true, &cfg);
        oneshot.append(&tail(&x, 30), &|| x.clone());
        assert_eq!(
            chunked.lambda().data,
            oneshot.lambda().data,
            "chunk boundaries must not change the factor"
        );
    }
}
