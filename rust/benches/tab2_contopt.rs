//! Table 2 — continuous-optimization baselines vs CV-LR on the
//! *discrete* SACHS network (App. B.2): SCORE, GraN-DAG, NOTEARS,
//! DAGMA, CV-LR; F1 (↑) and normalized SHD (↓).
//!
//! Paper shape to reproduce: the contopt methods collapse on discrete
//! data (F1 ≤ ~0.4; SCORE fails outright) while CV-LR stays ≈ 0.9.
//!
//! ```text
//! cargo bench --bench tab2_contopt [-- --full]
//! ```
//! Smoke: n = 500, 3 reps. Full: n = 2000, 10 reps (paper setting).

use std::sync::Arc;

use cvlr::bench::{mean_std, BenchConfig, Report};
use cvlr::contopt::dagma::{dagma, DagmaConfig};
use cvlr::contopt::grandag::{grandag, GranDagConfig};
use cvlr::contopt::notears::{notears, NotearsConfig};
use cvlr::contopt::score_method::{score_method, ScoreMethodConfig};
use cvlr::coordinator::{discover, DiscoveryConfig};
use cvlr::data::networks;
use cvlr::graph::pdag::dag_to_cpdag;
use cvlr::graph::{normalized_shd, skeleton_f1, Dag};
use cvlr::linalg::Mat;

/// Run one contopt method on the raw data matrix, returning its DAG.
/// SCORE assumes a nonlinear ANM with a density — on discretized levels
/// its Stein solve can fail; report that as None (the paper marks it −).
fn run_contopt(name: &str, x: &Mat) -> Option<Dag> {
    match name {
        "NOTEARS" => Some(notears(x, &NotearsConfig::default()).0),
        "DAGMA" => Some(dagma(x, &DagmaConfig::default()).0),
        "GraN-DAG" => Some(grandag(x, &GranDagConfig::default()).0),
        "SCORE" => std::panic::catch_unwind(|| {
            score_method(x, &ScoreMethodConfig::default())
        })
        .ok(),
        _ => unreachable!(),
    }
}

fn main() {
    let cfg = BenchConfig::from_env(2, 10);
    let n = if cfg.full { 2000 } else { cfg.args.usize_or("n", 500) };
    let net = networks::sachs();

    let mut rep = Report::new(
        &cfg,
        "tab2_contopt",
        &["method", "n", "f1_mean", "f1_std", "shd_mean", "shd_std"],
    );

    for name in ["SCORE", "GraN-DAG", "NOTEARS", "DAGMA", "CV-LR"] {
        let mut f1s = vec![];
        let mut shds = vec![];
        let mut failed = false;
        for r in 0..cfg.reps {
            let ds = Arc::new(networks::forward_sample(&net, n, cfg.seed + r as u64));
            let cpdag = if name == "CV-LR" {
                match discover(ds, &DiscoveryConfig::default()) {
                    Ok(out) => out.cpdag,
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            } else {
                match run_contopt(name, &ds.data) {
                    Some(dag) => dag_to_cpdag(&dag),
                    None => {
                        failed = true;
                        break;
                    }
                }
            };
            f1s.push(skeleton_f1(&cpdag, &net.dag));
            shds.push(normalized_shd(&cpdag, &net.dag));
        }
        if failed || f1s.is_empty() {
            println!("{name:<9} —        (cannot handle this setting)");
            rep.row(&[name.into(), n.to_string(), "".into(), "".into(), "".into(), "".into()]);
            continue;
        }
        let (f1m, f1sd) = mean_std(&f1s);
        let (shm, shsd) = mean_std(&shds);
        println!("{name:<9} F1={f1m:.3}±{f1sd:.3}  SHD={shm:.3}±{shsd:.3}");
        rep.row(&[
            name.into(),
            n.to_string(),
            format!("{f1m:.4}"),
            format!("{f1sd:.4}"),
            format!("{shm:.4}"),
            format!("{shsd:.4}"),
        ]);
    }
    rep.finish(&format!("Table 2 — discrete SACHS (n = {n})"));
    println!(
        "expected shape (paper, n=2000): CV-LR F1 0.94 / SHD 0.10;\n\
         DAGMA 0.42/0.24, GraN-DAG 0.27/0.25, NOTEARS 0.19/0.27, SCORE −"
    );
}
