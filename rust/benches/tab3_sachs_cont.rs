//! Table 3 — normalized SHD on the *continuous* SACHS dataset
//! (n = 853, App. B.3): SCORE, GraN-DAG, NOTEARS, DAGMA, PC, CV, CV-LR.
//!
//! Paper shape to reproduce: CV = CV-LR = best (0.1818); PC and SCORE
//! mid-pack; the contopt methods trail.
//!
//! ```text
//! cargo bench --bench tab3_sachs_cont [-- --full]
//! ```
//! The exact CV score over n = 853 × ~400 GES evaluations is hours of
//! O(n³) work, so CV runs on `--full` only (smoke reports CV at a
//! subsample, marked in the output).

use std::sync::Arc;

use cvlr::bench::{mean_std, BenchConfig, Report};
use cvlr::contopt::dagma::{dagma, DagmaConfig};
use cvlr::contopt::grandag::{grandag, GranDagConfig};
use cvlr::contopt::notears::{notears, NotearsConfig};
use cvlr::contopt::score_method::{score_method, ScoreMethodConfig};
use cvlr::coordinator::{discover, DiscoveryConfig, Method};
use cvlr::data::networks;
use cvlr::graph::pdag::dag_to_cpdag;
use cvlr::graph::normalized_shd;
use cvlr::util::timing::fmt_secs;

fn main() {
    let cfg = BenchConfig::from_env(2, 10);
    let n = 853; // the paper's continuous SACHS sample size
    let cv_n = if cfg.full { n } else { cfg.args.usize_or("cv-n", 200) };

    let mut rep = Report::new(&cfg, "tab3_sachs_cont", &["method", "n", "shd_mean", "shd_std", "secs"]);

    let mut run = |name: &str, reps: usize, f: &dyn Fn(u64) -> Option<(cvlr::graph::Pdag, f64)>| {
        let mut shds = vec![];
        let mut secs = vec![];
        for r in 0..reps {
            match f(cfg.seed + r as u64) {
                Some((cpdag, s)) => {
                    let (_, truth) = networks::sachs_continuous(8, 0); // structure only
                    shds.push(normalized_shd(&cpdag, &truth));
                    secs.push(s);
                }
                None => {
                    println!("{name:<9} —  (cannot handle this setting)");
                    rep.row(&[name.into(), n.to_string(), "".into(), "".into(), "".into()]);
                    return;
                }
            }
        }
        let (shm, shsd) = mean_std(&shds);
        let (tm, _) = mean_std(&secs);
        println!("{name:<9} SHD={shm:.4}±{shsd:.4}   {}", fmt_secs(tm));
        rep.row(&[
            name.into(),
            n.to_string(),
            format!("{shm:.4}"),
            format!("{shsd:.4}"),
            format!("{tm:.3}"),
        ]);
    };

    for method_name in ["SCORE", "GraN-DAG", "NOTEARS", "DAGMA"] {
        run(method_name, cfg.reps, &|seed| {
            let (ds, _) = networks::sachs_continuous(n, seed);
            let sw = cvlr::util::Stopwatch::start();
            let dag = match method_name {
                "NOTEARS" => notears(&ds.data, &NotearsConfig::default()).0,
                "DAGMA" => dagma(&ds.data, &DagmaConfig::default()).0,
                "GraN-DAG" => grandag(&ds.data, &GranDagConfig::default()).0,
                "SCORE" => score_method(&ds.data, &ScoreMethodConfig::default()),
                _ => unreachable!(),
            };
            Some((dag_to_cpdag(&dag), sw.secs()))
        });
    }

    // PC/KCI at n = 853 means O(n³) eigendecompositions per CI test —
    // smoke runs it on a subsample (the paper's own PC runs took hours).
    let pc_n = if cfg.full { n } else { cfg.args.usize_or("pc-n", 200) };
    let pc_label = if pc_n == n { "PC".to_string() } else { format!("PC(n={pc_n})") };
    run(&pc_label, cfg.reps.min(2), &|seed| {
        let (ds, _) = networks::sachs_continuous(pc_n, seed);
        discover(Arc::new(ds), &DiscoveryConfig { method: Method::Pc, ..Default::default() })
            .ok()
            .map(|o| (o.cpdag, o.seconds))
    });
    run("CV-LR", cfg.reps, &|seed| {
        let (ds, _) = networks::sachs_continuous(n, seed);
        discover(Arc::new(ds), &DiscoveryConfig { method: Method::CvLr, ..Default::default() })
            .ok()
            .map(|o| (o.cpdag, o.seconds))
    });

    // exact CV — O(n³): full scale on --full only
    let cv_label = if cv_n == n { "CV".to_string() } else { format!("CV(n={cv_n})") };
    run(&cv_label, 1, &|seed| {
        let (ds, _) = networks::sachs_continuous(cv_n, seed);
        discover(Arc::new(ds), &DiscoveryConfig { method: Method::Cv, ..Default::default() })
            .ok()
            .map(|o| (o.cpdag, o.seconds))
    });

    rep.finish(&format!("Table 3 — continuous SACHS (n = {n})"));
    println!(
        "expected shape (paper): CV = CV-LR best (0.1818); PC/SCORE 0.2182;\n\
         NOTEARS 0.2364, GraN-DAG 0.2727, DAGMA 0.3091"
    );
}
