//! Table 1 — CV vs CV-LR score values and relative error across the
//! four §7.2 settings and sample sizes, m = 100; plus the §7.2 sampling-
//! parameter (m) sweep behind `--sweep-m`.
//!
//! Paper shape to reproduce: relative error < 0.5% everywhere, < 0.1%
//! for discrete data (where Algorithm 2 is exact) and for continuous
//! |Z| = 0.
//!
//! ```text
//! cargo bench --bench tab1_accuracy [-- --full] [--sweep-m]
//! ```

use std::sync::Arc;

use cvlr::bench::{BenchConfig, Report};
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::{networks, Dataset};
use cvlr::lowrank::LowRankConfig;
use cvlr::score::cv_exact::CvExactScore;
use cvlr::score::cvlr::{CvLrScore, NativeCvLrKernel};
use cvlr::score::folds::CvParams;
use cvlr::score::LocalScore;

fn dataset_for(discrete: bool, n: usize, seed: u64) -> Arc<Dataset> {
    if discrete {
        let net = networks::child();
        Arc::new(networks::forward_sample(&net, n, seed))
    } else {
        let (ds, _) = generate(&SynthConfig {
            n,
            num_vars: 7,
            density: 0.5,
            kind: DataKind::Continuous,
            seed,
        });
        Arc::new(ds)
    }
}

fn main() {
    let cfg = BenchConfig::from_env(1, 1);
    // the exact CV score is the cost bottleneck: n ≤ 1000 smoke, ≤ 4000 full
    let sizes: &[usize] =
        if cfg.full { &[200, 500, 1000, 2000, 4000] } else { &[200, 500, 1000] };

    if cfg.args.flag("sweep-m") {
        sweep_m(&cfg);
        return;
    }

    let mut rep = Report::new(
        &cfg,
        "tab1_accuracy",
        &["setting", "n", "cv_score", "cvlr_score", "rel_error_pct"],
    );
    for (name, discrete, cond) in [
        ("Continu. |Z|=0", false, 0usize),
        ("Discrete |Z|=0", true, 0),
        ("Continu. |Z|=6", false, 6),
        ("Discrete |Z|=6", true, 6),
    ] {
        for &n in sizes {
            let ds = dataset_for(discrete, n, cfg.seed);
            let parents: Vec<usize> = (1..=cond).collect();
            let cv = CvExactScore::new(ds.clone(), CvParams::default());
            let lr = CvLrScore::native(ds);
            let s_cv = cv.local_score(0, &parents);
            let s_lr = lr.local_score(0, &parents);
            let rel = ((s_cv - s_lr) / s_cv).abs() * 100.0;
            println!("{name:<16} n={n:<5} CV={s_cv:<18.8} CV-LR={s_lr:<18.8} rel={rel:.4}%");
            rep.row(&[
                name.to_string(),
                n.to_string(),
                format!("{s_cv:.8}"),
                format!("{s_lr:.8}"),
                format!("{rel:.5}"),
            ]);
        }
    }
    rep.finish("Table 1 — CV vs CV-LR score accuracy (m = 100)");
    println!("expected: rel error < 0.5% everywhere; < 0.1% for discrete and |Z|=0 rows");
}

/// §7.2: relative error as a function of the rank cap m.
fn sweep_m(cfg: &BenchConfig) {
    let n = cfg.args.usize_or("n", 500);
    let mut rep = Report::new(
        cfg,
        "tab1_sweep_m",
        &["setting", "m", "rel_error_pct", "rank_used"],
    );
    for (name, discrete, cond) in
        [("Continu. |Z|=6", false, 6usize), ("Discrete |Z|=6", true, 6)]
    {
        let ds = dataset_for(discrete, n, cfg.seed);
        let parents: Vec<usize> = (1..=cond).collect();
        let cv = CvExactScore::new(ds.clone(), CvParams::default());
        let s_cv = cv.local_score(0, &parents);
        for m in [10, 20, 40, 60, 80, 100, 128] {
            let lr = CvLrScore::with_backend(
                ds.clone(),
                CvParams::default(),
                LowRankConfig { max_rank: m, eta: 1e-6, ..Default::default() },
                NativeCvLrKernel,
            );
            let s_lr = lr.local_score(0, &parents);
            let rank = lr.factor_for(&parents).cols.max(lr.factor_for(&[0]).cols);
            let rel = ((s_cv - s_lr) / s_cv).abs() * 100.0;
            println!("{name:<16} m={m:<4} rel={rel:.4}%  (max factor rank {rank})");
            rep.row(&[
                name.to_string(),
                m.to_string(),
                format!("{rel:.5}"),
                rank.to_string(),
            ]);
        }
    }
    rep.finish("§7.2 — relative error vs rank cap m (n = fixed)");
    println!("expected: error decreasing in m; m=100 meets the 0.5% budget");
}
