//! Fig. 1 — runtime of a single CV vs CV-LR local-score evaluation,
//! continuous & discrete data, |Z| ∈ {0, 6}, across sample sizes —
//! with CV-LR measured per low-rank factorization (ICL adaptive pivots
//! vs data-independent RFF), so the accuracy/speed trade between the
//! two is *recorded*, not asserted.
//!
//! Paper shape to reproduce: CV grows ~n³ while CV-LR stays ~linear;
//! the speedup ratio explodes with n, largest for discrete |Z|=0
//! (10,000x at n=4000 in the paper) and smallest for continuous |Z|=6.
//! On discrete data both factorization settings route through
//! Algorithm 2 (exact, and independent of the `--lowrank` knob), so
//! their rows should coincide; the continuous rows carry the ICL-vs-RFF
//! comparison.
//!
//! ```text
//! cargo bench --bench fig1_runtime [-- --full] [--lowrank icl,rff] [--shards 0,2]
//! ```
//! Smoke scale caps the exact CV at n ≤ 1000 (it is the O(n³) baseline;
//! an n = 4000 exact score takes minutes); `--full` runs the paper's
//! n ∈ {200, 500, 1000, 2000, 4000} everywhere. `--lowrank` restricts
//! the factorization axis (default: both).
//!
//! The `shards` axis records distributed scoring next to local:
//! `shards=0` rows time one fresh local score per rep, a `shards=k` row
//! times one wide batch of distinct candidates fanned out over an
//! in-process k-follower fleet (`ShardScoreBackend` over real TCP to
//! follower servers), reported per score — so the wire + partition
//! overhead of the fleet is *recorded* against the local baseline.

use std::sync::Arc;

use cvlr::bench::{BenchConfig, Report};
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::data::{networks, Dataset};
use cvlr::distrib::{PoolConfig, ShardScoreBackend};
use cvlr::lowrank::{FactorMethod, LowRankConfig};
use cvlr::obs::mem;
use cvlr::score::cv_exact::CvExactScore;
use cvlr::score::cvlr::{CvLrScore, NativeCvLrKernel};
use cvlr::score::folds::CvParams;
use cvlr::score::{LocalScore, ScalarBackend, ScoreBackend, ScoreRequest};
use cvlr::server::{Server, ServerConfig};
use cvlr::util::timing::{bench_fn, fmt_secs};

/// The four panels of Fig. 1.
struct Setting {
    name: &'static str,
    discrete: bool,
    cond: usize, // |Z|
}

const SETTINGS: [Setting; 4] = [
    Setting { name: "continuous |Z|=0", discrete: false, cond: 0 },
    Setting { name: "continuous |Z|=6", discrete: false, cond: 6 },
    Setting { name: "discrete   |Z|=0", discrete: true, cond: 0 },
    Setting { name: "discrete   |Z|=6", discrete: true, cond: 6 },
];

fn dataset_for(discrete: bool, n: usize, seed: u64) -> Arc<Dataset> {
    if discrete {
        // CHILD-style discrete data (§7.2 uses CHILD samples)
        let net = networks::child();
        Arc::new(networks::forward_sample(&net, n, seed))
    } else {
        let (ds, _) = generate(&SynthConfig {
            n,
            num_vars: 7,
            density: 0.5,
            kind: DataKind::Continuous,
            seed,
        });
        Arc::new(ds)
    }
}

fn main() {
    let cfg = BenchConfig::from_env(3, 5);
    let sizes: [usize; 5] = [200, 500, 1000, 2000, 4000];
    // exact CV cost cap on the smoke scale
    let cv_cap = if cfg.full { usize::MAX } else { 1000 };
    // Gram-product threads of the fold-core builds (--parallelism P)
    let parallelism = cfg.args.usize_or("parallelism", 1);
    // the ICL-vs-RFF axis: `--lowrank icl,rff` (default both)
    let lowrank: Vec<FactorMethod> = cfg
        .args
        .get_or("lowrank", "icl,rff")
        .split(',')
        .map(|s| {
            FactorMethod::parse(s.trim())
                .unwrap_or_else(|| panic!("unknown --lowrank `{s}` (icl|rff)"))
        })
        .collect();
    // the distributed axis: `--shards 0,2` (fleet sizes; 0 = local)
    let shard_axis: Vec<usize> = cfg
        .args
        .get_or("shards", "0,2")
        .split(',')
        .map(|s| {
            s.trim().parse().unwrap_or_else(|_| panic!("non-integer --shards value `{s}`"))
        })
        .collect();
    // in-process follower fleet, grown lazily to the largest axis value
    let mut fleet: Vec<Server> = Vec::new();

    let mut rep = Report::new(
        &cfg,
        "fig1_runtime",
        &[
            "setting",
            "lowrank",
            "shards",
            "n",
            "cv_seconds",
            "cvlr_seconds",
            "cvlr_seconds_p50",
            "cvlr_seconds_p95",
            "speedup",
            "peak_bytes",
            "peak_bytes_per_row",
        ],
    );

    for s in &SETTINGS {
        for &n in &sizes {
            let ds = dataset_for(s.discrete, n, cfg.seed);
            let target = 0usize;
            let parents: Vec<usize> = (1..=s.cond).collect();

            // exact CV — O(n³), the shared baseline for every
            // factorization row; skipped above the smoke cap.
            let cv_mean = if n <= cv_cap {
                let st = bench_fn(0, if cfg.full { cfg.reps } else { 1 }, || {
                    let cv = CvExactScore::new(ds.clone(), CvParams::default());
                    let _ = cv.local_score(target, &parents);
                });
                Some(st.mean_s)
            } else {
                None
            };

            for &lm in &lowrank {
                for &k in &shard_axis {
                    // CV-LR per-score seconds. `shards=0`: a fresh local
                    // score per rep so the factor and fold-core caches
                    // do not amortize across reps. `shards=k`: one wide
                    // batch of distinct candidates through a k-follower
                    // fleet, per score — registration and the follower
                    // service build stay outside the timed region (they
                    // amortize over a sweep in real use).
                    // peak-delta window around the timed region: rebase
                    // the allocator high-water marks, measure, and read
                    // back the process peak over the baseline — this is
                    // the memory trajectory the O(n)-space gate checks
                    let (lr_mean, lr_p50, lr_p95, peak) = if k == 0 {
                        let baseline = mem::reset_peak();
                        let st = bench_fn(1, cfg.reps, || {
                            let lr = CvLrScore::with_backend(
                                ds.clone(),
                                CvParams::default(),
                                LowRankConfig::with_method(lm),
                                NativeCvLrKernel,
                            )
                            .with_parallelism(parallelism);
                            let _ = lr.local_score(target, &parents);
                        });
                        let peak = mem::peak_bytes().saturating_sub(baseline);
                        (st.mean_s, st.p50_s, st.p95_s, peak)
                    } else {
                        while fleet.len() < k {
                            fleet.push(
                                Server::start(ServerConfig {
                                    port: 0,
                                    job_workers: 1,
                                    builtin_n: 40,
                                    ..Default::default()
                                })
                                .expect("follower starts"),
                            );
                        }
                        let addrs: Vec<String> =
                            fleet[..k].iter().map(|f| f.addr().to_string()).collect();
                        let lr = CvLrScore::with_backend(
                            ds.clone(),
                            CvParams::default(),
                            LowRankConfig::with_method(lm),
                            NativeCvLrKernel,
                        )
                        .with_parallelism(parallelism);
                        let local: Arc<dyn ScoreBackend> = Arc::new(ScalarBackend(lr));
                        let name = format!(
                            "fig1-{}-z{}-{}-{}",
                            if s.discrete { "disc" } else { "cont" },
                            s.cond,
                            lm.name(),
                            n
                        );
                        let backend = ShardScoreBackend::new(
                            local,
                            &ds,
                            &name,
                            "cv-lr",
                            "native",
                            lm.name(),
                            &addrs,
                            PoolConfig { min_remote: 1, ..Default::default() },
                        );
                        // dataset push + follower service build happen on
                        // first contact; keep them out of the timed batch
                        let _ = backend.score_batch(&[ScoreRequest::new(target, &parents)]);
                        let d = ds.d();
                        let reqs: Vec<ScoreRequest> = (1..d)
                            .map(|t| {
                                let ps: Vec<usize> =
                                    (1..=s.cond).map(|j| (t + j) % d).collect();
                                ScoreRequest::new(t, &ps)
                            })
                            .collect();
                        // one rep: the follower-side score memo would turn
                        // a second rep into a cache-hit measurement
                        let baseline = mem::reset_peak();
                        let st = bench_fn(0, 1, || {
                            let _ = backend.score_batch(&reqs);
                        });
                        let peak = mem::peak_bytes().saturating_sub(baseline);
                        let per = reqs.len() as f64;
                        (st.mean_s / per, st.p50_s / per, st.p95_s / per, peak)
                    };

                    let speedup = cv_mean.map(|c| c / lr_mean);
                    println!(
                        "{:<18} {:<4} shards={} n={:<5} CV={:<10} CV-LR={:<10} speedup={:<8} peak={}KiB",
                        s.name,
                        lm.name(),
                        k,
                        n,
                        cv_mean.map(fmt_secs).unwrap_or_else(|| "-".into()),
                        fmt_secs(lr_mean),
                        speedup.map(|x| format!("{x:.0}x")).unwrap_or_else(|| "-".into()),
                        peak / 1024
                    );
                    rep.row(&[
                        s.name.trim().to_string(),
                        lm.name().to_string(),
                        k.to_string(),
                        n.to_string(),
                        cv_mean.map(|x| format!("{x:.6}")).unwrap_or_default(),
                        format!("{lr_mean:.6}"),
                        format!("{lr_p50:.6}"),
                        format!("{lr_p95:.6}"),
                        speedup.map(|x| format!("{x:.1}")).unwrap_or_default(),
                        peak.to_string(),
                        format!("{:.1}", peak as f64 / n as f64),
                    ]);
                }
            }
        }
    }
    for f in fleet {
        f.stop();
    }
    rep.finish("Fig. 1 — single-score runtime, CV vs CV-LR (per factorization)");
    println!(
        "expected shape: CV ~ n³, CV-LR ~ n; largest ratios for discrete |Z|=0\n\
         (paper: 150x at n=4000 |Z|=6; 2,000x continuous / 10,000x discrete |Z|=0);\n\
         rff rows trade the adaptive-pivot error bound for data independence"
    );
}
