//! Ablations over the design choices called out in DESIGN.md:
//!
//! 1. factorization algorithm — Algorithm 2 (exact discrete) vs
//!    Algorithm 1 (ICL) on the same discrete data: rank + time + score
//!    agreement (the paper's §4 motivation for the specialized path);
//! 2. scoring backend — native rust dumbbell algebra vs the AOT XLA
//!    artifacts via PJRT: per-score latency across sample sizes
//!    (quantifies the PJRT dispatch overhead the coordinator amortizes);
//! 3. coordinator cache — GES evaluations and wall-clock with the score
//!    service cache on vs off;
//! 4. worker pool — batch throughput at 1/2/4/8 workers.
//!
//! ```text
//! cargo bench --bench ablation_engine [-- --full]
//! ```

use std::sync::Arc;

use cvlr::bench::{BenchConfig, Report};
use cvlr::coordinator::ScoreService;
use cvlr::data::networks;
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::kernel::{median_heuristic, Kernel};
use cvlr::lowrank::{factorize, LowRankConfig};
use cvlr::runtime::pjrt_kernel::PjrtCvLrKernel;
use cvlr::runtime::Runtime;
use cvlr::score::cvlr::CvLrScore;
use cvlr::score::folds::CvParams;
use cvlr::score::{LocalScore, ScalarBackend, ScoreBackend, ScoreRequest};
use cvlr::search::ges::{ges, GesConfig};
use cvlr::util::timing::{bench_fn, fmt_secs};
use cvlr::util::Stopwatch;

fn main() {
    let cfg = BenchConfig::from_env(3, 10);
    ablation_factorization(&cfg);
    ablation_backend(&cfg);
    ablation_cache(&cfg);
    ablation_workers(&cfg);
}

/// 1. Algorithm 2 vs Algorithm 1 on discrete data.
fn ablation_factorization(cfg: &BenchConfig) {
    let mut rep = Report::new(
        cfg,
        "ablation_factorization",
        &["n", "algorithm", "rank", "seconds", "recon_max_err"],
    );
    let net = networks::child();
    for n in [500usize, 2000] {
        let ds = networks::forward_sample(&net, n, cfg.seed);
        let block = ds.block_multi(&[0, 1, 2]); // 3-variable discrete set
        let kern = Kernel::Rbf { sigma: median_heuristic(&block, 2.0) };
        for (name, discrete) in [("Alg2-discrete", true), ("Alg1-ICL", false)] {
            let sw = Stopwatch::start();
            let lr = factorize(kern, &block, discrete, &LowRankConfig::default());
            let secs = sw.secs();
            // reconstruction error on a probe of entries
            let mut err = 0.0f64;
            for i in (0..n).step_by((n / 64).max(1)) {
                for j in (0..n).step_by((n / 64).max(1)) {
                    let truth = kern.eval(block.row(i), block.row(j));
                    let mut approx = 0.0;
                    for c in 0..lr.lambda.cols {
                        approx += lr.lambda[(i, c)] * lr.lambda[(j, c)];
                    }
                    err = err.max((truth - approx).abs());
                }
            }
            println!(
                "n={n:<5} {name:<14} rank={:<4} {}  max_err={err:.2e}",
                lr.rank,
                fmt_secs(secs)
            );
            rep.row(&[
                n.to_string(),
                name.into(),
                lr.rank.to_string(),
                format!("{secs:.6}"),
                format!("{err:.3e}"),
            ]);
        }
    }
    rep.finish("Ablation 1 — discrete factorization: Algorithm 2 vs ICL");
}

/// 2. native vs PJRT per-score latency.
fn ablation_backend(cfg: &BenchConfig) {
    let rt = match Runtime::load("artifacts") {
        Ok(rt) => Arc::new(rt),
        Err(e) => {
            println!("(skipping backend ablation: {e})");
            return;
        }
    };
    let mut rep = Report::new(
        cfg,
        "ablation_backend",
        &["n", "backend", "score_seconds"],
    );
    for n in [200usize, 500, 1000, 2000] {
        let (ds, _) = generate(&SynthConfig {
            n,
            num_vars: 7,
            density: 0.5,
            kind: DataKind::Continuous,
            seed: cfg.seed,
        });
        let ds = Arc::new(ds);
        let native = CvLrScore::native(ds.clone());
        let pjrt = CvLrScore::with_backend(
            ds,
            CvParams::default(),
            Default::default(),
            PjrtCvLrKernel::new(rt.clone()),
        );
        // warm the factor cache so only the fold-kernel backend differs
        let _ = native.local_score(0, &[1, 2]);
        let _ = pjrt.local_score(0, &[1, 2]);
        let st_native = bench_fn(0, cfg.reps, || {
            let _ = native.local_score(0, &[1, 2]);
        });
        let st_pjrt = bench_fn(0, cfg.reps, || {
            let _ = pjrt.local_score(0, &[1, 2]);
        });
        println!(
            "n={n:<5} native={:<10} pjrt={:<10} overhead={:.1}x",
            fmt_secs(st_native.mean_s),
            fmt_secs(st_pjrt.mean_s),
            st_pjrt.mean_s / st_native.mean_s.max(1e-12)
        );
        rep.row(&[n.to_string(), "native".into(), format!("{:.6}", st_native.mean_s)]);
        rep.row(&[n.to_string(), "pjrt".into(), format!("{:.6}", st_pjrt.mean_s)]);
    }
    rep.finish("Ablation 2 — scoring backend: native vs PJRT artifacts");
}

/// 3. GES with vs without the score-service cache.
fn ablation_cache(cfg: &BenchConfig) {
    let mut rep = Report::new(
        cfg,
        "ablation_cache",
        &["cache", "evaluations", "seconds"],
    );
    let (ds, _) = generate(&SynthConfig {
        n: 300,
        num_vars: 7,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: cfg.seed,
    });
    let ds = Arc::new(ds);

    // cached: the ScoreService counts unique evaluations
    let svc = ScoreService::new(Arc::new(CvLrScore::native(ds.clone())), 1);
    let sw = Stopwatch::start();
    let _ = ges(&svc, &GesConfig::default());
    let cached_secs = sw.secs();
    let st = svc.stats();
    println!(
        "cache=on   evals={:<6} requests={:<6} {}",
        st.evaluations,
        st.requests,
        fmt_secs(cached_secs)
    );
    rep.row(&["on".into(), st.evaluations.to_string(), format!("{cached_secs:.4}")]);

    // uncached: raw score straight into GES (every request re-evaluated)
    struct Uncached(CvLrScore<cvlr::score::cvlr::NativeCvLrKernel>, std::sync::atomic::AtomicU64);
    impl LocalScore for Uncached {
        fn local_score(&self, t: usize, p: &[usize]) -> f64 {
            self.1.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.0.local_score(t, p)
        }
        fn num_vars(&self) -> usize {
            // qualified: CvLrScore implements both LocalScore and
            // ScoreBackend, and both traits are in scope here
            LocalScore::num_vars(&self.0)
        }
    }
    let raw = ScalarBackend(Uncached(CvLrScore::native(ds), std::sync::atomic::AtomicU64::new(0)));
    let sw = Stopwatch::start();
    let _ = ges(&raw, &GesConfig::default());
    let raw_secs = sw.secs();
    let evals = raw.0 .1.load(std::sync::atomic::Ordering::Relaxed);
    println!("cache=off  evals={:<6} {}  ({:.1}x slower)", evals, fmt_secs(raw_secs), raw_secs / cached_secs.max(1e-12));
    rep.row(&["off".into(), evals.to_string(), format!("{raw_secs:.4}")]);
    rep.finish("Ablation 3 — coordinator dedup cache");
}

/// 4. batch throughput vs worker count.
fn ablation_workers(cfg: &BenchConfig) {
    let mut rep = Report::new(cfg, "ablation_workers", &["workers", "batch_seconds", "req_per_s"]);
    let (ds, _) = generate(&SynthConfig {
        n: 400,
        num_vars: 10,
        density: 0.4,
        kind: DataKind::Continuous,
        seed: cfg.seed,
    });
    let ds = Arc::new(ds);
    // a GES-step-like batch: one insert-candidate scan
    let reqs: Vec<ScoreRequest> = (0..10usize)
        .flat_map(|y| {
            (0..10usize).filter(move |&x| x != y).map(move |x| ScoreRequest::new(y, &[x]))
        })
        .collect();
    for workers in [1usize, 2, 4, 8] {
        let svc = ScoreService::new(Arc::new(CvLrScore::native(ds.clone())), workers);
        let sw = Stopwatch::start();
        let _ = svc.score_batch(&reqs);
        let secs = sw.secs();
        println!(
            "workers={workers}  batch of {} in {}  ({:.1} req/s)",
            reqs.len(),
            fmt_secs(secs),
            reqs.len() as f64 / secs.max(1e-12)
        );
        rep.row(&[
            workers.to_string(),
            format!("{secs:.4}"),
            format!("{:.1}", reqs.len() as f64 / secs.max(1e-12)),
        ]);
    }
    rep.finish("Ablation 4 — score-service worker pool");
}
