//! Fig. 2 / 3 / 4 — F1 and normalized SHD of recovered causal graphs on
//! synthetic FCM data: density sweep {0.2..0.8} × data kind
//! {continuous, mixed, multi-dim} × sample size n ∈ {200, 500, 1000} ×
//! method {CV-LR, CV, BIC, BDeu, SC, PC, MM}.
//!
//! Paper shape to reproduce: CV-LR ≈ CV everywhere; kernel scores lead
//! at high density and on multi-dim data; constraint-based methods
//! (PC/MM) degrade as density grows; BIC/SC trail on nonlinear data.
//!
//! ```text
//! cargo bench --bench fig2_4_synthetic [-- --full]
//! ```
//! Smoke: n = 200, reps = 3, methods {CV-LR, BIC, SC, PC}. Full: the
//! paper grid with 20 reps and all methods (CV included — hours).

use std::sync::Arc;

use cvlr::bench::{mean_std, BenchConfig, Report};
use cvlr::coordinator::{discover, DiscoveryConfig, Method};
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::graph::{normalized_shd, skeleton_f1};
use cvlr::lowrank::FactorMethod;
use cvlr::obs::mem;

fn applicable(method: Method, kind: DataKind) -> bool {
    match method {
        // BDeu requires all-discrete data; none of the synthetic kinds
        // is fully discrete (mixed is 50/50), matching the paper's plots
        // where BDeu only appears on discrete networks.
        Method::Bdeu => false,
        // SC (Spearman BIC) is undefined for multi-dimensional variables
        // (§7.1): skip it there.
        Method::Sc => kind != DataKind::MultiDim,
        // BIC assumes scalar continuous variables; on multi-dim data the
        // paper's causal-learn BIC treats each block — our BicScore
        // handles blocks, so keep it (it just performs poorly).
        _ => true,
    }
}

fn main() {
    let cfg = BenchConfig::from_env(2, 20);
    let sizes: &[usize] = if cfg.full { &[200, 500, 1000] } else { &[200] };
    let methods: &[Method] = if cfg.full {
        &[Method::CvLr, Method::Cv, Method::Bic, Method::Sc, Method::Pc, Method::Mm]
    } else {
        &[Method::CvLr, Method::Bic, Method::Sc, Method::Pc]
    };
    let kinds = [
        (DataKind::Continuous, "continuous"),
        (DataKind::Mixed, "mixed"),
        (DataKind::MultiDim, "multidim"),
    ];
    let densities = [0.2, 0.4, 0.6, 0.8];

    let mut rep = Report::new(
        &cfg,
        "fig2_4_synthetic",
        &[
            "n", "kind", "density", "method", "lowrank", "f1_mean", "f1_std", "shd_mean",
            "shd_std", "secs_mean", "peak_bytes", "peak_bytes_per_row",
        ],
    );

    for &n in sizes {
        for (kind, kname) in kinds {
            for &density in &densities {
                for &method in methods {
                    if !applicable(method, kind) {
                        continue;
                    }
                    // CV-LR carries the factorization axis (ICL vs
                    // data-independent RFF); every other method has no
                    // low-rank knob and records one "-" row
                    let axis: &[Option<FactorMethod>] = if method == Method::CvLr {
                        &[Some(FactorMethod::Icl), Some(FactorMethod::Rff)]
                    } else {
                        &[None]
                    };
                    for &lm in axis {
                        let mut f1s = vec![];
                        let mut shds = vec![];
                        let mut secs = vec![];
                        // high-water delta across every rep of this cell
                        let baseline = mem::reset_peak();
                        for r in 0..cfg.reps {
                            let (ds, dag) = generate(&SynthConfig {
                                n,
                                num_vars: 7,
                                density,
                                kind,
                                seed: cfg.seed + 131 * r as u64,
                            });
                            let mut dcfg = DiscoveryConfig { method, ..Default::default() };
                            if let Some(m) = lm {
                                dcfg.lowrank.method = m;
                            }
                            match discover(Arc::new(ds), &dcfg) {
                                Ok(out) => {
                                    f1s.push(skeleton_f1(&out.cpdag, &dag));
                                    shds.push(normalized_shd(&out.cpdag, &dag));
                                    secs.push(out.seconds);
                                }
                                Err(e) => eprintln!(
                                    "  {} failed on {kname} density {density}: {e}",
                                    method.name()
                                ),
                            }
                        }
                        let peak = mem::peak_bytes().saturating_sub(baseline);
                        if f1s.is_empty() {
                            continue;
                        }
                        let lname = lm.map(|m| m.name()).unwrap_or("-");
                        let (f1m, f1s_) = mean_std(&f1s);
                        let (shm, shs) = mean_std(&shds);
                        let (tm, _) = mean_std(&secs);
                        println!(
                            "n={n:<5} {kname:<10} density={density:.1} {:<6} {lname:<4} F1={f1m:.3}±{f1s_:.3} SHD={shm:.3}±{shs:.3} {tm:.2}s",
                            method.name()
                        );
                        rep.row(&[
                            n.to_string(),
                            kname.to_string(),
                            format!("{density:.1}"),
                            method.name().to_string(),
                            lname.to_string(),
                            format!("{f1m:.4}"),
                            format!("{f1s_:.4}"),
                            format!("{shm:.4}"),
                            format!("{shs:.4}"),
                            format!("{tm:.3}"),
                            peak.to_string(),
                            format!("{:.1}", peak as f64 / n as f64),
                        ]);
                    }
                }
            }
        }
    }
    rep.finish("Fig. 2-4 — synthetic-data accuracy sweep");
    println!(
        "expected shape: CV-LR ≈ CV; kernel scores lead at high density and\n\
         multi-dim data; PC/MM degrade with density; BIC/SC trail on nonlinear\n\
         data; CV-LR/rff trades a little F1 for data-independent factors"
    );
}
