//! Fig. 5 — F1 vs sample size on the discrete SACHS and CHILD networks,
//! plus the runtime bars (CV vs CV-LR) at the largest n.
//!
//! Paper shape to reproduce: CV-LR ≈ CV in F1 at every n (best on
//! SACHS; BDeu competitive on CHILD); F1 grows with n; CV-LR learns
//! SACHS n=2000 in seconds while CV needs hours (600-1000x).
//!
//! ```text
//! cargo bench --bench fig5_realworld [-- --full]
//! ```
//! Smoke: n ∈ {200, 500, 1000}, 2 reps, PC at n = 200 only, runtime bars
//! on SACHS at n = 200. Full: n ∈ {200, .., 2000}, 20 reps, CV at 2000.

use std::sync::Arc;

use cvlr::bench::{mean_std, BenchConfig, Report};
use cvlr::coordinator::{discover, DiscoveryConfig, Method};
use cvlr::data::networks;
use cvlr::graph::{normalized_shd, skeleton_f1};
use cvlr::util::timing::fmt_secs;

fn main() {
    let cfg = BenchConfig::from_env(2, 20);
    let sizes: &[usize] = if cfg.full { &[200, 500, 1000, 2000] } else { &[200, 500, 1000] };
    let methods = [Method::CvLr, Method::Bdeu, Method::Sc, Method::Pc];
    // KCI's eigendecompositions are O(n³) per test — on the smoke scale
    // PC only runs at n = 200 (the paper's own PC/KCI runs took hours).
    let pc_cap = if cfg.full { usize::MAX } else { 200 };

    let mut rep = Report::new(
        &cfg,
        "fig5_realworld",
        &["network", "n", "method", "f1_mean", "f1_std", "shd_mean", "secs_mean"],
    );

    for net_fn in [networks::sachs, networks::child] {
        let net = net_fn();
        for &n in sizes {
            for &method in &methods {
                if method == Method::Pc && n > pc_cap {
                    continue;
                }
                let mut f1s = vec![];
                let mut shds = vec![];
                let mut secs = vec![];
                for r in 0..cfg.reps {
                    let ds = Arc::new(networks::forward_sample(&net, n, cfg.seed + r as u64));
                    match discover(ds, &DiscoveryConfig { method, ..Default::default() }) {
                        Ok(out) => {
                            f1s.push(skeleton_f1(&out.cpdag, &net.dag));
                            shds.push(normalized_shd(&out.cpdag, &net.dag));
                            secs.push(out.seconds);
                        }
                        Err(e) => eprintln!("  {} failed: {e}", method.name()),
                    }
                }
                if f1s.is_empty() {
                    continue;
                }
                let (f1m, f1sd) = mean_std(&f1s);
                let (shm, _) = mean_std(&shds);
                let (tm, _) = mean_std(&secs);
                println!(
                    "{:<6} n={n:<5} {:<6} F1={f1m:.3}±{f1sd:.3} SHD={shm:.3} {}",
                    net.name,
                    method.name(),
                    fmt_secs(tm)
                );
                rep.row(&[
                    net.name.to_string(),
                    n.to_string(),
                    method.name().to_string(),
                    format!("{f1m:.4}"),
                    format!("{f1sd:.4}"),
                    format!("{shm:.4}"),
                    format!("{tm:.4}"),
                ]);
            }
        }
    }

    // ---- runtime bars: CV vs CV-LR at the largest workable n ----
    let cv_n = if cfg.full { 2000 } else { cfg.args.usize_or("cv-n", 200) };
    println!("\n-- runtime bars (n = {cv_n}) --");
    let mut bars = Report::new(&cfg, "fig5_runtime_bars", &["network", "method", "n", "seconds"]);
    // exact-CV GES over 20-node CHILD is minutes even at n = 200 — the
    // smoke bars cover SACHS only (--full runs both at n = 2000).
    let bar_nets: &[fn() -> networks::DiscreteNetwork] =
        if cfg.full { &[networks::sachs, networks::child] } else { &[networks::sachs] };
    for net_fn in bar_nets {
        let net = net_fn();
        let ds = Arc::new(networks::forward_sample(&net, cv_n, cfg.seed));
        let out_lr = discover(ds.clone(), &DiscoveryConfig::default()).expect("cvlr run");
        let out_cv = discover(ds, &DiscoveryConfig { method: Method::Cv, ..Default::default() })
            .expect("cv run");
        println!(
            "{:<6} CV={}  CV-LR={}  speedup={:.0}x",
            net.name,
            fmt_secs(out_cv.seconds),
            fmt_secs(out_lr.seconds),
            out_cv.seconds / out_lr.seconds.max(1e-12)
        );
        bars.row(&[net.name.into(), "CV".into(), cv_n.to_string(), format!("{:.4}", out_cv.seconds)]);
        bars.row(&[net.name.into(), "CV-LR".into(), cv_n.to_string(), format!("{:.4}", out_lr.seconds)]);
    }
    bars.finish("Fig. 5 right — full-search runtime, CV vs CV-LR");
    rep.finish("Fig. 5 — real-world networks accuracy");
    println!(
        "expected shape: CV-LR best-or-tied on SACHS, BDeu competitive on CHILD;\n\
         F1 increases with n; CV/CV-LR full-search speedup 600-1000x at n=2000"
    );
}
