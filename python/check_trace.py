#!/usr/bin/env python3
"""Validate a Chrome trace-event JSON file written by ``--trace-out``
or scraped from ``GET /v1/trace``.

Usage:

    python3 check_trace.py TRACE.json [--require-remote] [--require NAME ...]

Checks (stdlib only — runs on any CI image):

* the document is a JSON object with a ``traceEvents`` list;
* every ``X`` (complete-span) event carries ``name``, ``cat``, numeric
  ``ts``/``dur`` and integer ``pid``/``tid``;
* every ``i`` (instant) event carries ``name``, numeric ``ts`` and a
  thread scope;
* every pid referenced by an event has ``process_name`` metadata, and
  every (pid, tid) pair has ``thread_name`` metadata — without these
  Perfetto shows anonymous tracks;
* with ``--require-remote``: at least one span is follower-attributed
  (pid >= 2; pid 1 is the local process), i.e. fleet timing propagation
  actually merged remote events;
* with ``--require NAME``: a span or instant with that name exists
  (e.g. ``ges-forward-sweep``).

Exits non-zero with a message on the first failure; prints an event
census on success.
"""

import argparse
import json
import sys
from collections import Counter


def fail(msg):
    sys.exit(f"check_trace: FAIL: {msg}")


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace JSON file")
    ap.add_argument(
        "--require-remote",
        action="store_true",
        help="require at least one follower-attributed span (pid >= 2)",
    )
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="NAME",
        help="require an event with this name (repeatable)",
    )
    args = ap.parse_args()

    try:
        with open(args.trace) as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {args.trace}: {e}")

    if not isinstance(doc, dict):
        fail("top level is not a JSON object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        fail("`traceEvents` is missing or not a list")

    spans = instants = 0
    names = Counter()
    pids_used = set()
    tids_used = set()
    proc_named = set()
    thread_named = set()
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(f"event #{i} is not an object")
        ph = ev.get("ph")
        if ph == "M":
            pid = ev.get("pid")
            if ev.get("name") == "process_name":
                proc_named.add(pid)
            elif ev.get("name") == "thread_name":
                thread_named.add((pid, ev.get("tid")))
            continue
        name = ev.get("name")
        if not isinstance(name, str) or not name:
            fail(f"event #{i} ({ph!r}) has no name")
        pid, tid = ev.get("pid"), ev.get("tid")
        if not isinstance(pid, int) or not isinstance(tid, int):
            fail(f"event #{i} ({name}) has non-integer pid/tid")
        if not isinstance(ev.get("ts"), (int, float)):
            fail(f"event #{i} ({name}) has non-numeric ts")
        if ph == "X":
            if not isinstance(ev.get("cat"), str):
                fail(f"span #{i} ({name}) has no cat")
            if not isinstance(ev.get("dur"), (int, float)):
                fail(f"span #{i} ({name}) has non-numeric dur")
            spans += 1
        elif ph == "i":
            if "s" not in ev:
                fail(f"instant #{i} ({name}) has no scope")
            instants += 1
        else:
            fail(f"event #{i} ({name}) has unknown phase {ph!r}")
        names[name] += 1
        pids_used.add(pid)
        tids_used.add((pid, tid))

    for pid in sorted(pids_used):
        if pid not in proc_named:
            fail(f"pid {pid} has events but no process_name metadata")
    for pid, tid in sorted(tids_used):
        if (pid, tid) not in thread_named:
            fail(f"(pid {pid}, tid {tid}) has events but no thread_name metadata")

    if args.require_remote and not any(p >= 2 for p in pids_used):
        fail("no follower-attributed span (pid >= 2) in the trace")
    for want in args.require:
        if names[want] == 0:
            fail(f"required event `{want}` absent")

    top = ", ".join(f"{n}×{c}" for n, c in names.most_common(6))
    print(
        f"check_trace: OK: {spans} span(s), {instants} instant(s) across "
        f"{len(pids_used)} process(es) / {len(tids_used)} thread track(s); top: {top}"
    )


if __name__ == "__main__":
    main()
