"""L1 Pallas kernel: tiled pairwise RBF (Gaussian) kernel matrix.

The exact-CV baseline score (paper Eq. 8/9) needs full n×n kernel
matrices K_ij = exp(−‖x_i − x_j‖² / 2σ²) — its O(n²d) construction is
one of the two exact-path hot spots (the other being the O(n³) solves).

Tiling: 2-D grid over (row tiles × col tiles); each step loads one
(block × d) tile of each operand into VMEM and emits a (block × block)
output tile using the ‖x‖² + ‖y‖² − 2xyᵀ expansion, so the MXU handles
the cross-term contraction. interpret=True on this CPU-only image.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


def _rbf_kernel(x_ref, y_ref, inv_ref, o_ref):
    x = x_ref[...]
    y = y_ref[...]
    xx = jnp.sum(x * x, axis=1, keepdims=True)       # (bx, 1)
    yy = jnp.sum(y * y, axis=1, keepdims=True).T      # (1, by)
    xy = jnp.dot(x, y.T, preferred_element_type=o_ref.dtype)
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-d2 * inv_ref[0])


def rbf_cross(x: jax.Array, y: jax.Array, sigma: jax.Array, block: int = BLOCK) -> jax.Array:
    """K(x, y) with K_ij = exp(−‖x_i−y_j‖²/(2σ²)); shapes (nx×d),(ny×d).

    σ is a traced scalar (the median-heuristic width is data-dependent
    and computed by the rust coordinator at run time)."""
    nx, d = x.shape
    ny, d2 = y.shape
    assert d == d2
    bx = block if nx % block == 0 else nx
    by = block if ny % block == 0 else ny
    inv = (0.5 / (sigma * sigma)).reshape((1,))
    return pl.pallas_call(
        _rbf_kernel,
        grid=(nx // bx, ny // by),
        in_specs=[
            pl.BlockSpec((bx, d), lambda i, j: (i, 0)),
            pl.BlockSpec((by, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bx, by), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nx, ny), x.dtype),
        interpret=True,
    )(x, y, inv)
