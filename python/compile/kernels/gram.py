"""L1 Pallas kernel: tiled Gram products AᵀB.

The CV-LR score's only O(n·m²) work is forming the six m×m cores
P,E,F,V,U,S = Λᵀ·Λ cross-products (paper §5); everything downstream is
O(m³). This kernel expresses that reduction TPU-style:

* the sample axis n is the grid's reduction dimension — each grid step
  streams one (block_n × m) tile of each factor from HBM into VMEM and
  accumulates its (m × m) outer contribution in the output block, which
  stays resident in VMEM across the grid (standard Pallas accumulation
  pattern);
* tile sizes: block_n=256, m≤128 → 256·128·8B = 256 KiB per operand
  tile (f64), comfortably double-bufferable in 16 MiB VMEM; the MXU
  sees (m × block_n)·(block_n × m) contractions.

On this CPU-only image the kernel must run with interpret=True (Mosaic
custom-calls cannot execute on CPU PJRT) — see DESIGN.md §Hardware
adaptation; numerics are validated against `ref.gram_ref` by pytest.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default reduction tile (rows of the factor streamed per grid step).
BLOCK_N = 256


def _gram_kernel(a_ref, b_ref, o_ref):
    """One grid step: o += a_tileᵀ @ b_tile (accumulate across the grid)."""

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, b_ref[...], preferred_element_type=o_ref.dtype
    )


def gram_tt(a: jax.Array, b: jax.Array, block_n: int = BLOCK_N) -> jax.Array:
    """Compute aᵀ @ b for (n × ma), (n × mb) factors via the Pallas tile
    reduction. n must be divisible by the chosen block (callers use
    power-of-two shape buckets; for small inputs the whole axis becomes
    one block)."""
    n, ma = a.shape
    n_b, mb = b.shape
    assert n == n_b, f"row mismatch {n} vs {n_b}"
    if n % block_n != 0:
        block_n = n  # single-tile fallback for odd/small sizes
    grid = (n // block_n,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, ma), lambda i: (i, 0)),
            pl.BlockSpec((block_n, mb), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ma, mb), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((ma, mb), a.dtype),
        interpret=True,
    )(a, b)
