"""Pure-jnp oracles for the L1 Pallas kernels and a literal, dense
implementation of the paper's Eq. (8)/(9) used to validate the L2
dumbbell-form score graphs.

Everything here is O(n²)/O(n³) on purpose — these are the correctness
references, never the production path.
"""

import jax.numpy as jnp


def gram_ref(a, b):
    """aᵀ @ b."""
    return a.T @ b


def rbf_ref(x, y, sigma):
    """Dense pairwise RBF kernel."""
    d2 = (
        jnp.sum(x * x, axis=1)[:, None]
        + jnp.sum(y * y, axis=1)[None, :]
        - 2.0 * (x @ y.T)
    )
    return jnp.exp(-jnp.maximum(d2, 0.0) / (2.0 * sigma * sigma))


def cv_cond_dense_ref(lx0, lx1, lz0, lz1, n0, n1, lam, gam):
    """Paper Eq. (8) computed literally on the dense kernel matrices
    reconstructed from the (already centered) low-rank factors:
    K̃ₓ¹ = Λ̃ₓ₁Λ̃ₓ₁ᵀ etc. O(n³) — the oracle for `model.cvlr_cond`."""
    kx11 = lx1 @ lx1.T
    kx01 = lx0 @ lx1.T
    kz11 = lz1 @ lz1.T
    kz01 = lz0 @ lz1.T
    tr_kx00 = jnp.trace(lx0 @ lx0.T)
    beta = lam * lam / gam
    nn1 = kx11.shape[0]

    a = jnp.linalg.inv(kz11 + n1 * lam * jnp.eye(nn1))
    b = a @ kx11 @ a
    q = n1 * beta * b + jnp.eye(nn1)
    sign, logdet = jnp.linalg.slogdet(q)
    c = a @ jnp.linalg.inv(q) @ a

    t1 = tr_kx00
    t2 = jnp.trace(kz01 @ b @ kz01.T)
    t3 = jnp.trace(kx01 @ a @ kz01.T)
    t4 = jnp.trace(kx01 @ c @ kx01.T)
    t5 = jnp.trace(kz01 @ a @ kx11 @ c @ kx11 @ a @ kz01.T)
    t6 = jnp.trace(kx01 @ c @ kx11 @ a @ kz01.T)
    trace_total = t1 + t2 - 2 * t3 - n1 * beta * t4 - n1 * beta * t5 + 2 * n1 * beta * t6

    return (
        -(n0 * n0 / 2) * jnp.log(2 * jnp.pi)
        - (n0 / 2) * logdet
        - (n0 * n1 / 2) * jnp.log(gam)
        - trace_total / (2 * gam)
    )


def cv_marg_dense_ref(lx0, lx1, n0, n1, lam, gam):
    """Paper Eq. (9) (§5 "|z|=0" form) on dense matrices from factors."""
    kx11 = lx1 @ lx1.T
    kx01 = lx0 @ lx1.T
    tr_kx00 = jnp.trace(lx0 @ lx0.T)
    nn1 = kx11.shape[0]

    q = jnp.eye(nn1) + kx11 / (n1 * lam)
    sign, logdet = jnp.linalg.slogdet(q)
    bchk = jnp.linalg.inv(q)
    t2 = jnp.trace(kx01 @ bchk @ kx01.T)
    trace_total = tr_kx00 - t2 / (n1 * gam)

    return (
        -(n0 * n0 / 2) * jnp.log(2 * jnp.pi)
        - (n0 / 2) * logdet
        - (n0 * n1 / 2) * jnp.log(gam)
        - trace_total / (2 * gam)
    )
