"""AOT lowering: JAX score graphs → HLO *text* artifacts + manifest.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (all f64):

* ``cvlr_cond_n{N}`` / ``cvlr_marg_n{N}`` for the shape buckets
  N ∈ {256, 512, 1024, 2048, 4096}: one CV fold of the paper's CV-LR
  score from zero-padded centered factors (N1 = N train rows,
  N0 = N/4 test rows, M = 128 columns) + true-count/λ/γ scalars.
  Padding is exact (DESIGN.md §2), so one bucket serves every n ≤ N.
* ``exact_cond_n{n}`` / ``exact_marg_n{n}`` for
  n ∈ {200, 500, 1000, 2000, 4000}: one fold of the exact O(n³) CV
  score from raw fold data (n0 = n/10 test rows, n1 = 9n/10 train
  rows; feature dims padded to DX=8 / DZ=32) — the Fig. 1 baseline,
  running through the same PJRT runtime as CV-LR.

``manifest.json`` (written last — it is the Makefile's stamp file)
records every artifact's shapes for the rust runtime.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# CV-LR factor shape buckets (train rows; test rows = N/4, columns = M).
CVLR_BUCKETS = [256, 512, 1024, 2048, 4096]
# Column (rank) buckets: the adaptive low-rank algorithms usually stop
# well below the m=100 cap (single variables and small discrete sets are
# rank ≲ 30), and the artifact pays Gram FLOPs for every padded column —
# a 32-column bucket cuts that 16x on the common path (EXPERIMENTS.md
# §Perf, L3 iteration 1).
M_BUCKETS = [32, 128]
M = 128
# Exact-CV sample sizes (Fig. 1 / Table 1 sweep; 10-fold → n1 = 0.9n).
EXACT_SIZES = [200, 500, 1000, 2000, 4000]
DX = 8
DZ = 32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def lower_all(out_dir: str) -> list[dict]:
    entries = []

    def emit(name, fn, specs, meta):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({"name": name, "file": fname, **meta})
        print(f"  {name}: {len(text)} chars")

    scalar = _spec()
    for n1 in CVLR_BUCKETS:
        n0 = n1 // 4
        for m in M_BUCKETS:
            emit(
                f"cvlr_cond_n{n1}_m{m}",
                lambda lx0, lx1, lz0, lz1, a, b, c, d: (model.cvlr_cond(lx0, lx1, lz0, lz1, a, b, c, d),),
                [_spec(n0, m), _spec(n1, m), _spec(n0, m), _spec(n1, m), scalar, scalar, scalar, scalar],
                {"kind": "cvlr_cond", "n1_cap": n1, "n0_cap": n0, "m": m},
            )
            emit(
                f"cvlr_marg_n{n1}_m{m}",
                lambda lx0, lx1, a, b, c, d: (model.cvlr_marg(lx0, lx1, a, b, c, d),),
                [_spec(n0, m), _spec(n1, m), scalar, scalar, scalar, scalar],
                {"kind": "cvlr_marg", "n1_cap": n1, "n0_cap": n0, "m": m},
            )

    for n in EXACT_SIZES:
        n0, n1 = n // 10, n - n // 10
        emit(
            f"exact_cond_n{n}",
            lambda x0, x1, z0, z1, sx, sz, lam, gam: (model.cv_exact_cond(x0, x1, z0, z1, sx, sz, lam, gam),),
            [_spec(n0, DX), _spec(n1, DX), _spec(n0, DZ), _spec(n1, DZ), scalar, scalar, scalar, scalar],
            {"kind": "exact_cond", "n": n, "n0": n0, "n1": n1, "dx": DX, "dz": DZ},
        )
        emit(
            f"exact_marg_n{n}",
            lambda x0, x1, sx, lam, gam: (model.cv_exact_marg(x0, x1, sx, lam, gam),),
            [_spec(n0, DX), _spec(n1, DX), scalar, scalar, scalar],
            {"kind": "exact_marg", "n": n, "n0": n0, "n1": n1, "dx": DX},
        )

    return entries


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    print(f"lowering score graphs to {args.out} (f64, HLO text)")
    entries = lower_all(args.out)
    manifest = {
        "dtype": "f64",
        "cvlr_buckets": CVLR_BUCKETS,
        "m_buckets": M_BUCKETS,
        "exact_sizes": EXACT_SIZES,
        "m": M,
        "dx": DX,
        "dz": DZ,
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(entries)} artifacts")


if __name__ == "__main__":
    main()
