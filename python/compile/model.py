"""L2: the CV-LR and exact-CV score functions as JAX computation graphs.

Built once by `aot.py` into fixed-shape HLO-text artifacts that the rust
coordinator executes through PJRT — python never runs on the request
path.

Shape conventions (DESIGN.md §2):

* `cvlr_cond` / `cvlr_marg` take *zero-padded* centered factors
  Λ̃ (rows padded with zeros beyond the true n₀/n₁, columns padded with
  zeros beyond the true m) plus the true sample counts as f64 scalars.
  Both paddings are exact no-ops for the score: zero rows contribute
  nothing to any Gram product, and zero columns extend every dumbbell
  core block-diagonally with identity/zero blocks.
* `cv_exact_cond` / `cv_exact_marg` take raw fold data (train/test
  sample blocks, zero-padded in the *feature* dimension only, which RBF
  distances ignore) and the kernel widths as scalars; the row counts are
  static shapes, so these artifacts are compiled per (n₀, n₁) pair.

All graphs are f64 (`jax_enable_x64`), matching the rust reference
bit-for-bit up to BLAS reduction order.
"""

import jax
import jax.numpy as jnp

from .kernels.gram import gram_tt
from .kernels.rbf import rbf_cross

jax.config.update("jax_enable_x64", True)

LOG_2PI = float(jnp.log(2 * jnp.pi))


def _chol_logdet_inv(q):
    """(log|Q|, Q⁻¹) for an SPD matrix via unpivoted Gauss-Jordan.

    Deliberately NOT `jnp.linalg.cholesky` + `cho_solve`: those lower to
    LAPACK FFI custom-calls (`lapack_dpotrf_ffi`, `lapack_dtrsm_ffi`)
    which the pinned xla_extension 0.5.1 PJRT cannot compile
    ("Unknown custom-call API version enum value: 4"). The Gauss-Jordan
    sweep lowers to a pure-HLO while loop + dynamic slices, and is
    numerically equivalent to LDLᵀ for SPD inputs (no pivoting needed:
    every Schur complement of an SPD matrix is SPD, so the pivots stay
    positive — they also directly give log|Q| = Σ log pivotₖ).
    """
    m = q.shape[0]
    dtype = q.dtype
    idx = jnp.arange(m)

    def body(k, carry):
        a, inv, logdet = carry
        p = a[k, k]
        logdet = logdet + jnp.log(p)
        arow = a[k, :] / p
        irow = inv[k, :] / p
        colm = jnp.where(idx == k, 0.0, a[:, k])
        a = a - jnp.outer(colm, arow)
        inv = inv - jnp.outer(colm, irow)
        a = a.at[k, :].set(arow)
        inv = inv.at[k, :].set(irow)
        return a, inv, logdet

    _, inv, logdet = jax.lax.fori_loop(
        0, m, body, (q, jnp.eye(m, dtype=dtype), jnp.zeros((), dtype))
    )
    return logdet, inv


def cvlr_cond(lx0, lx1, lz0, lz1, n0, n1, lam, gam):
    """One fold of the conditional CV-LR score (paper §5, Eq. 26).

    lx0,lz0: (N0, M) padded test factors; lx1,lz1: (N1, M) padded train
    factors; n0,n1: true counts (f64 scalars); lam,gam: λ, γ.
    """
    beta = lam * lam / gam
    c1 = 1.0 / (n1 * lam)

    # O(n·m²): the six dumbbell cores, via the L1 Pallas kernel.
    p = gram_tt(lx1, lx1)   # P  (M×M)
    e = gram_tt(lz1, lx1)   # E
    f = gram_tt(lz1, lz1)   # F
    v = gram_tt(lx0, lx0)   # V
    u = gram_tt(lz0, lx0)   # U
    s = gram_tt(lz0, lz0)   # S

    eye_x = jnp.eye(p.shape[0], dtype=p.dtype)
    eye_z = jnp.eye(f.shape[0], dtype=f.dtype)

    # D = (n₁λI + F)⁻¹
    _, d = _chol_logdet_inv(f + n1 * lam * eye_z)
    de = d @ e
    t = p - 2.0 * (e.T @ de) + de.T @ (f @ de)  # Eq. 17 core

    # Q = I + T/(n₁γ): log|Q| = log|n₁βB + I| (Eq. 20-21); G = Q⁻¹
    logdet, g = _chol_logdet_inv(eye_x + t / (n1 * gam))

    # W = c₁²T − n₁β c₁⁴ · T G T  ( = Λ̃ₓ₁ᵀ C Λ̃ₓ₁ )
    w = c1 * c1 * t - (n1 * beta * c1**4) * (t @ g @ t)

    # M₂ = V − 2c₁·Eᵀ(I−DF)U + c₁²·Eᵀ(I−DF)S(I−DF)ᵀE   (Eq. 26)
    idf = eye_z - d @ f
    et_idf = e.T @ idf
    m2 = v - 2.0 * c1 * (et_idf @ u) + c1 * c1 * (et_idf @ s @ et_idf.T)

    total_trace = jnp.trace(m2) - n1 * beta * jnp.sum(w * m2.T)

    return (
        -(n0 * n0 / 2.0) * LOG_2PI
        - (n0 / 2.0) * logdet
        - (n0 * n1 / 2.0) * jnp.log(gam)
        - total_trace / (2.0 * gam)
    )


def cvlr_marg(lx0, lx1, n0, n1, lam, gam):
    """One fold of the marginal (|Z|=0) CV-LR score (Eq. 27-30)."""
    c1 = 1.0 / (n1 * lam)
    p = gram_tt(lx1, lx1)
    v = gram_tt(lx0, lx0)
    m = p.shape[0]
    eye = jnp.eye(m, dtype=p.dtype)

    logdet, dchk = _chol_logdet_inv(eye + c1 * p)
    vp = v @ p
    tr_vp = jnp.trace(vp)
    tr_vpdp = jnp.sum((vp @ dchk) * p.T)
    trace_total = jnp.trace(v) - (tr_vp - c1 * tr_vpdp) / (n1 * gam)

    return (
        -(n0 * n0 / 2.0) * LOG_2PI
        - (n0 / 2.0) * logdet
        - (n0 * n1 / 2.0) * jnp.log(gam)
        - trace_total / (2.0 * gam)
    )


# ---------------------------------------------------------------------------
# Exact CV (the O(n³) baseline), computed end-to-end on device: RBF
# kernels from the L1 Pallas kernel, train-mean centering, Eq. 8/9.
# ---------------------------------------------------------------------------


def _centered_blocks(x0, x1, sigma):
    """Kernel blocks of one fold, centered by the train mean:
    returns (K̃¹¹ (n1×n1), K̃⁰¹ (n0×n1), Tr K̃⁰⁰)."""
    n1 = x1.shape[0]
    k11 = rbf_cross(x1, x1, sigma)
    k01 = rbf_cross(x0, x1, sigma)
    colmean = jnp.mean(k11, axis=0)          # (n1,)
    grand = jnp.mean(k11)
    rowmean01 = jnp.mean(k01, axis=1)        # (n0,)
    k11c = k11 - colmean[:, None] - colmean[None, :] + grand
    k01c = k01 - rowmean01[:, None] - colmean[None, :] + grand
    # RBF diag is 1: Tr K̃⁰⁰ = Σ_i (1 − 2·rowmean01_i + grand)
    tr_k00 = jnp.sum(1.0 - 2.0 * rowmean01 + grand)
    del n1
    return k11c, k01c, tr_k00


def cv_exact_cond(x0, x1, z0, z1, sigx, sigz, lam, gam):
    """One fold of the exact conditional CV score (Eq. 8). Row counts are
    static; feature dims may be zero-padded."""
    n0 = float(x0.shape[0])
    n1 = float(x1.shape[0])
    beta = lam * lam / gam

    kx11, kx01, tr_kx00 = _centered_blocks(x0, x1, sigx)
    kz11, kz01, _ = _centered_blocks(z0, z1, sigz)
    nn1 = kx11.shape[0]
    eye = jnp.eye(nn1, dtype=kx11.dtype)

    _, a = _chol_logdet_inv(kz11 + n1 * lam * eye)
    ax = a @ kx11
    b = ax @ a
    logdet, qinv = _chol_logdet_inv(n1 * beta * b + eye)
    c = a @ qinv @ a

    t1 = tr_kx00
    zb = kz01 @ b
    t2 = jnp.sum(zb * kz01)
    t3 = jnp.sum((kx01 @ a) * kz01)
    xc = kx01 @ c
    t4 = jnp.sum(xc * kx01)
    zax = kz01 @ a @ kx11
    t5 = jnp.sum((zax @ c) * zax)
    t6 = jnp.sum((xc @ kx11 @ a) * kz01)
    trace_total = t1 + t2 - 2 * t3 - n1 * beta * t4 - n1 * beta * t5 + 2 * n1 * beta * t6

    return (
        -(n0 * n0 / 2.0) * LOG_2PI
        - (n0 / 2.0) * logdet
        - (n0 * n1 / 2.0) * jnp.log(gam)
        - trace_total / (2.0 * gam)
    )


def cv_exact_marg(x0, x1, sigx, lam, gam):
    """One fold of the exact marginal CV score (Eq. 9)."""
    n0 = float(x0.shape[0])
    n1 = float(x1.shape[0])

    kx11, kx01, tr_kx00 = _centered_blocks(x0, x1, sigx)
    nn1 = kx11.shape[0]
    eye = jnp.eye(nn1, dtype=kx11.dtype)

    logdet, bchk = _chol_logdet_inv(eye + kx11 / (n1 * lam))
    t2 = jnp.sum((kx01 @ bchk) * kx01)
    trace_total = tr_kx00 - t2 / (n1 * gam)

    return (
        -(n0 * n0 / 2.0) * LOG_2PI
        - (n0 / 2.0) * logdet
        - (n0 * n1 / 2.0) * jnp.log(gam)
        - trace_total / (2.0 * gam)
    )
