#!/usr/bin/env python3
"""Assert structural properties of a cvlr Prometheus snapshot.

Stdlib-only validator for the text exposition the server serves at
``GET /v1/metrics`` and the CLI writes via ``--metrics-out`` — the CI
smoke jobs gate on it instead of grepping raw text:

    python3 check_metrics.py FILE.prom \
        [--require-scope SCOPE]...        # cvlr_mem_peak_bytes{scope=...} > 0
        [--require-follower ADDR]...      # a follower="ADDR"-labeled series exists
        [--require-exemplar]              # some histogram bucket carries an exemplar
        [--trace FILE.json]               # ...whose span id exists in this Chrome trace

Exemplar lines follow the OpenMetrics shape the registry renders:

    cvlr_score_batch_seconds_bucket{le="0.25"} 3 # {trace_span="17"} 0.0625

Exits 0 when every requirement holds, 1 with a diagnostic otherwise.
"""

import argparse
import json
import re
import sys

LABEL_RE = re.compile(r'([A-Za-z_][A-Za-z0-9_]*)="((?:[^"\\]|\\.)*)"')


def unescape(v):
    return v.replace("\\n", "\n").replace('\\"', '"').replace("\\\\", "\\")


def parse_exposition(text):
    """[(name, {label: value}, float value, exemplar-labels-or-None)]."""
    samples = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        # split off the OpenMetrics exemplar suffix first
        sample, exemplar = line, None
        if " # " in line:
            sample, suffix = line.split(" # ", 1)
            exemplar = {k: unescape(v) for k, v in LABEL_RE.findall(suffix)}
        if "{" in sample:
            name = sample[: sample.index("{")]
            rest = sample[sample.index("{") :]
            labels = {k: unescape(v) for k, v in LABEL_RE.findall(rest)}
            value_str = rest[rest.index("}") + 1 :].strip().split(" ")[0]
        else:
            parts = sample.split(" ")
            if len(parts) < 2:
                continue
            name, labels, value_str = parts[0], {}, parts[1]
        try:
            value = float(value_str)
        except ValueError:
            continue
        samples.append((name, labels, value, exemplar))
    return samples


def trace_span_ids(path):
    """Span ids exported in a Chrome trace-event JSON (args.span_id)."""
    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    ids = set()
    for ev in events:
        sid = (ev.get("args") or {}).get("span_id")
        if sid:
            ids.add(str(sid))
    return ids


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("prom", help="Prometheus text exposition file")
    ap.add_argument(
        "--require-scope",
        action="append",
        default=[],
        metavar="SCOPE",
        help="require cvlr_mem_peak_bytes{scope=SCOPE} with a nonzero value",
    )
    ap.add_argument(
        "--require-follower",
        action="append",
        default=[],
        metavar="ADDR",
        help='require at least one series labeled follower="ADDR"',
    )
    ap.add_argument(
        "--require-exemplar",
        action="store_true",
        help="require at least one histogram bucket exemplar",
    )
    ap.add_argument(
        "--trace",
        metavar="FILE.json",
        help="with --require-exemplar: some exemplar span id must exist in this trace",
    )
    args = ap.parse_args()

    with open(args.prom) as fh:
        samples = parse_exposition(fh.read())
    if not samples:
        sys.exit(f"error: no samples parsed from {args.prom}")

    failures = []

    for scope in args.require_scope:
        hit = any(
            name == "cvlr_mem_peak_bytes" and labels.get("scope") == scope and value > 0
            for name, labels, value, _ in samples
        )
        if not hit:
            seen = sorted(
                labels["scope"]
                for name, labels, value, _ in samples
                if name == "cvlr_mem_peak_bytes" and "scope" in labels and value > 0
            )
            failures.append(
                f'no nonzero cvlr_mem_peak_bytes{{scope="{scope}"}} (nonzero scopes: {seen})'
            )

    for addr in args.require_follower:
        hit = any(labels.get("follower") == addr for _, labels, _, _ in samples)
        if not hit:
            seen = sorted(
                {labels["follower"] for _, labels, _, _ in samples if "follower" in labels}
            )
            failures.append(f'no series labeled follower="{addr}" (followers seen: {seen})')

    if args.require_exemplar:
        exemplars = [
            (name, ex["trace_span"])
            for name, _, _, ex in samples
            if ex and "trace_span" in ex
        ]
        if not exemplars:
            failures.append("no histogram bucket carries an exemplar")
        elif args.trace:
            ids = trace_span_ids(args.trace)
            linked = [(n, s) for (n, s) in exemplars if s in ids]
            if not linked:
                failures.append(
                    f"no exemplar span id among {sorted({s for _, s in exemplars})} "
                    f"exists in {args.trace} ({len(ids)} trace spans)"
                )
            else:
                print(
                    f"ok: {len(linked)}/{len(exemplars)} exemplar(s) link to spans "
                    f"in {args.trace} (e.g. {linked[0][0]} -> span {linked[0][1]})"
                )

    if failures:
        for f in failures:
            print(f"check_metrics: FAIL: {f}", file=sys.stderr)
        sys.exit(1)
    print(
        f"check_metrics: ok ({len(samples)} samples; "
        f"scopes={args.require_scope or '-'}, followers={args.require_follower or '-'}, "
        f"exemplar={'yes' if args.require_exemplar else 'not required'})"
    )


if __name__ == "__main__":
    main()
