"""L2 correctness: the dumbbell-form score graphs vs the literal dense
Eq. (8)/(9) oracle, padding invariance, and exact-CV vs a numpy
re-implementation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_enable_x64", True)

LAM = 0.01
GAM = 0.01


def factors(n0, n1, mx, mz, seed):
    """Random centered fold factors (train-mean centering)."""
    rng = np.random.default_rng(seed)
    lx1 = rng.standard_normal((n1, mx))
    lz1 = rng.standard_normal((n1, mz))
    lx0 = rng.standard_normal((n0, mx))
    lz0 = rng.standard_normal((n0, mz))
    # center by train means (matching the runtime convention)
    lx0 -= lx1.mean(axis=0)
    lz0 -= lz1.mean(axis=0)
    lx1 -= lx1.mean(axis=0)
    lz1 -= lz1.mean(axis=0)
    return map(jnp.asarray, (lx0, lx1, lz0, lz1))


@settings(max_examples=10, deadline=None)
@given(
    n0=st.integers(5, 30),
    n1=st.integers(40, 120),
    mx=st.integers(2, 12),
    mz=st.integers(2, 12),
    seed=st.integers(0, 2**31),
)
def test_cond_matches_dense_oracle(n0, n1, mx, mz, seed):
    lx0, lx1, lz0, lz1 = factors(n0, n1, mx, mz, seed)
    got = model.cvlr_cond(lx0, lx1, lz0, lz1, float(n0), float(n1), LAM, GAM)
    want = ref.cv_cond_dense_ref(lx0, lx1, lz0, lz1, float(n0), float(n1), LAM, GAM)
    np.testing.assert_allclose(got, want, rtol=1e-9)


@settings(max_examples=10, deadline=None)
@given(
    n0=st.integers(5, 30),
    n1=st.integers(40, 120),
    mx=st.integers(2, 12),
    seed=st.integers(0, 2**31),
)
def test_marg_matches_dense_oracle(n0, n1, mx, seed):
    lx0, lx1, _, _ = factors(n0, n1, mx, 2, seed)
    got = model.cvlr_marg(lx0, lx1, float(n0), float(n1), LAM, GAM)
    want = ref.cv_marg_dense_ref(lx0, lx1, float(n0), float(n1), LAM, GAM)
    np.testing.assert_allclose(got, want, rtol=1e-9)


def pad(m, rows, cols):
    out = jnp.zeros((rows, cols), dtype=m.dtype)
    return out.at[: m.shape[0], : m.shape[1]].set(m)


def test_padding_invariance_cond():
    """Zero row+column padding must be an exact no-op — the property the
    fixed-shape artifacts rely on (true counts passed as scalars)."""
    n0, n1, mx, mz = 12, 90, 7, 5
    lx0, lx1, lz0, lz1 = factors(n0, n1, mx, mz, 7)
    s_ref = model.cvlr_cond(lx0, lx1, lz0, lz1, float(n0), float(n1), LAM, GAM)
    s_pad = model.cvlr_cond(
        pad(lx0, 64, 32), pad(lx1, 256, 32), pad(lz0, 64, 32), pad(lz1, 256, 32),
        float(n0), float(n1), LAM, GAM,
    )
    np.testing.assert_allclose(s_pad, s_ref, rtol=1e-10)


def test_padding_invariance_marg():
    n0, n1, mx = 9, 77, 6
    lx0, lx1, _, _ = factors(n0, n1, mx, 2, 8)
    s_ref = model.cvlr_marg(lx0, lx1, float(n0), float(n1), LAM, GAM)
    s_pad = model.cvlr_marg(pad(lx0, 64, 32), pad(lx1, 256, 32), float(n0), float(n1), LAM, GAM)
    np.testing.assert_allclose(s_pad, s_ref, rtol=1e-10)


def numpy_exact_cond(x0, x1, z0, z1, sigx, sigz, lam, gam):
    """Independent numpy implementation of Eq. 8 (train-mean centering)."""
    def blocks(a0, a1, sig):
        def k(p, q):
            d2 = ((p[:, None, :] - q[None, :, :]) ** 2).sum(-1)
            return np.exp(-d2 / (2 * sig * sig))
        k11 = k(a1, a1)
        k01 = k(a0, a1)
        cm = k11.mean(0)
        g = k11.mean()
        rm = k01.mean(1)
        k11c = k11 - cm[:, None] - cm[None, :] + g
        k01c = k01 - rm[:, None] - cm[None, :] + g
        tr00 = float(np.sum(1.0 - 2.0 * rm + g))
        return k11c, k01c, tr00

    n0, n1 = x0.shape[0], x1.shape[0]
    beta = lam * lam / gam
    kx11, kx01, trx = blocks(x0, x1, sigx)
    kz11, kz01, _ = blocks(z0, z1, sigz)
    a = np.linalg.inv(kz11 + n1 * lam * np.eye(n1))
    b = a @ kx11 @ a
    q = n1 * beta * b + np.eye(n1)
    logdet = np.linalg.slogdet(q)[1]
    c = a @ np.linalg.inv(q) @ a
    t = (
        trx
        + np.trace(kz01 @ b @ kz01.T)
        - 2 * np.trace(kx01 @ a @ kz01.T)
        - n1 * beta * np.trace(kx01 @ c @ kx01.T)
        - n1 * beta * np.trace(kz01 @ a @ kx11 @ c @ kx11 @ a @ kz01.T)
        + 2 * n1 * beta * np.trace(kx01 @ c @ kx11 @ a @ kz01.T)
    )
    return (
        -(n0 * n0 / 2) * np.log(2 * np.pi)
        - (n0 / 2) * logdet
        - (n0 * n1 / 2) * np.log(gam)
        - t / (2 * gam)
    )


def test_exact_cond_matches_numpy():
    rng = np.random.default_rng(3)
    n0, n1 = 8, 72
    x0 = rng.standard_normal((n0, 2))
    x1 = rng.standard_normal((n1, 2))
    z0 = rng.standard_normal((n0, 3))
    z1 = rng.standard_normal((n1, 3))
    got = model.cv_exact_cond(
        jnp.asarray(x0), jnp.asarray(x1), jnp.asarray(z0), jnp.asarray(z1),
        jnp.float64(1.3), jnp.float64(0.9), LAM, GAM,
    )
    want = numpy_exact_cond(x0, x1, z0, z1, 1.3, 0.9, LAM, GAM)
    np.testing.assert_allclose(got, want, rtol=1e-9)


def test_exact_equals_cvlr_on_exact_factors():
    """When Λ̃Λ̃ᵀ = K̃ exactly, CV-LR must reproduce the exact score:
    build data whose kernel admits an exact small factorization (a
    discrete variable) and compare through the dense oracle."""
    rng = np.random.default_rng(5)
    n0, n1 = 10, 90
    # dense rank-m factors serve as "exact" kernels by construction
    lx0, lx1, lz0, lz1 = factors(n0, n1, 6, 4, 11)
    dense = ref.cv_cond_dense_ref(lx0, lx1, lz0, lz1, float(n0), float(n1), LAM, GAM)
    lr = model.cvlr_cond(lx0, lx1, lz0, lz1, float(n0), float(n1), LAM, GAM)
    np.testing.assert_allclose(lr, dense, rtol=1e-9)


def test_scores_are_finite_at_scale():
    n0, n1 = 64, 256
    lx0, lx1, lz0, lz1 = factors(n0, n1, 100, 100, 13)
    s = model.cvlr_cond(lx0, lx1, lz0, lz1, float(n0), float(n1), LAM, GAM)
    assert np.isfinite(float(s))
