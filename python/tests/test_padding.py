"""Padding invariance of the L2 score graphs (DESIGN.md §2).

The fixed-shape HLO artifacts rely on two exact invariances:

* zero-COLUMN padding of the centered factors leaves every dumbbell
  core (hence traces and log-determinants) unchanged;
* zero-ROW padding (beyond the true n0/n1, which travel as scalars)
  contributes nothing to any Gram product.

These tests exercise the *actual lowered functions* used by aot.py, so
any regression here would corrupt every bucketed artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import _chol_logdet_inv, cvlr_cond, cvlr_marg

jax.config.update("jax_enable_x64", True)


def _factors(rng, n, m):
    lam = rng.normal(size=(n, m))
    return lam - lam.mean(axis=0, keepdims=True)


def _split(lam, n0):
    l0, l1 = lam[:n0], lam[n0:]
    mean = l1.mean(axis=0, keepdims=True)
    return l0 - mean, l1 - mean


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("pad_cols", [1, 17])
def test_cond_column_padding_exact(seed, pad_cols):
    rng = np.random.default_rng(seed)
    n, n0, m = 100, 10, 9
    lx0, lx1 = _split(_factors(rng, n, m), n0)
    lz0, lz1 = _split(_factors(rng, n, m - 3), n0)
    args = (float(n0), float(n - n0), 0.01, 0.01)
    s_ref = cvlr_cond(lx0, lx1, lz0, lz1, *args)
    pad = lambda a, extra: np.pad(a, [(0, 0), (0, extra)])
    s_pad = cvlr_cond(
        pad(lx0, pad_cols), pad(lx1, pad_cols), pad(lz0, pad_cols), pad(lz1, pad_cols), *args
    )
    np.testing.assert_allclose(s_pad, s_ref, rtol=1e-10)


@pytest.mark.parametrize("pad_rows", [1, 33])
def test_cond_row_padding_exact(pad_rows):
    rng = np.random.default_rng(2)
    n, n0, m = 80, 8, 6
    lx0, lx1 = _split(_factors(rng, n, m), n0)
    lz0, lz1 = _split(_factors(rng, n, m), n0)
    args = (float(n0), float(n - n0), 0.01, 0.01)
    s_ref = cvlr_cond(lx0, lx1, lz0, lz1, *args)
    padr = lambda a: np.pad(a, [(0, pad_rows), (0, 0)])
    # true n0/n1 stay the same scalars — only the buffer rows grow
    s_pad = cvlr_cond(padr(lx0), padr(lx1), padr(lz0), padr(lz1), *args)
    np.testing.assert_allclose(s_pad, s_ref, rtol=1e-10)


def test_marg_row_and_column_padding_exact():
    rng = np.random.default_rng(3)
    n, n0, m = 90, 9, 5
    lx0, lx1 = _split(_factors(rng, n, m), n0)
    args = (float(n0), float(n - n0), 0.01, 0.01)
    s_ref = cvlr_marg(lx0, lx1, *args)
    padded = lambda a: np.pad(a, [(0, 11), (0, 7)])
    s_pad = cvlr_marg(padded(lx0), padded(lx1), *args)
    np.testing.assert_allclose(s_pad, s_ref, rtol=1e-10)


def test_bucket_shapes_match_artifact_layout():
    """The padded call at exactly the artifact bucket shape equals the
    tight-shape call — the contract the rust runtime relies on."""
    rng = np.random.default_rng(4)
    n, n0, m = 180, 18, 12
    lx0, lx1 = _split(_factors(rng, n, m), n0)
    lz0, lz1 = _split(_factors(rng, n, m), n0)
    args = (float(n0), float(n - n0), 0.01, 0.01)
    s_ref = cvlr_cond(lx0, lx1, lz0, lz1, *args)
    # bucket: N1=256, N0=64, M=32 (the smallest runtime bucket pair)
    bpad = lambda a, rows: np.pad(a, [(0, rows - a.shape[0]), (0, 32 - a.shape[1])])
    s_bucket = cvlr_cond(bpad(lx0, 64), bpad(lx1, 256), bpad(lz0, 64), bpad(lz1, 256), *args)
    np.testing.assert_allclose(s_bucket, s_ref, rtol=1e-10)


# ---------------------------------------------------------------------------
# the pure-HLO Gauss-Jordan replacement for cholesky/cho_solve
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m", [1, 2, 7, 64])
def test_gauss_jordan_logdet_inv_matches_numpy(m):
    rng = np.random.default_rng(5)
    a = rng.normal(size=(m, m))
    q = a @ a.T + m * np.eye(m)
    logdet, inv = jax.jit(_chol_logdet_inv)(jnp.array(q))
    _, ld_ref = np.linalg.slogdet(q)
    np.testing.assert_allclose(logdet, ld_ref, rtol=1e-12)
    np.testing.assert_allclose(inv, np.linalg.inv(q), atol=1e-12)


def test_gauss_jordan_lowers_without_custom_calls():
    """The whole point: no LAPACK FFI custom-calls in the lowered HLO
    (xla_extension 0.5.1 cannot compile them)."""
    q = jnp.eye(16) * 2.0
    hlo = (
        jax.jit(_chol_logdet_inv)
        .lower(q)
        .compiler_ir(dialect="hlo")
        .as_hlo_text()
    )
    assert "custom-call" not in hlo and "custom_call" not in hlo


def test_full_cond_graph_lowers_without_custom_calls():
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float64)
    lowered = jax.jit(cvlr_cond).lower(
        spec(64, 32), spec(256, 32), spec(64, 32), spec(256, 32),
        spec(), spec(), spec(), spec(),
    )
    hlo = lowered.compiler_ir(dialect="hlo").as_hlo_text()
    assert "custom-call" not in hlo and "custom_call" not in hlo
