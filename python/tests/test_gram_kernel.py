"""L1 correctness: the Pallas gram kernel vs the pure-jnp oracle,
swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gram import gram_tt
from compile.kernels.ref import gram_ref

jax.config.update("jax_enable_x64", True)


def rand(shape, seed, dtype=jnp.float64):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


@settings(max_examples=25, deadline=None)
@given(
    n_blocks=st.integers(1, 4),
    ma=st.integers(1, 24),
    mb=st.integers(1, 24),
    seed=st.integers(0, 2**31),
)
def test_matches_ref_blocked(n_blocks, ma, mb, seed):
    # n divisible by the block → multi-step grid accumulation path
    n = 64 * n_blocks
    a = rand((n, ma), seed)
    b = rand((n, mb), seed + 1)
    got = gram_tt(a, b, block_n=64)
    np.testing.assert_allclose(got, gram_ref(a, b), rtol=1e-12, atol=1e-12)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 150), m=st.integers(1, 16), seed=st.integers(0, 2**31))
def test_single_tile_fallback(n, m, seed):
    # n not divisible by the default block → single-tile path
    a = rand((n, m), seed)
    got = gram_tt(a, a)
    np.testing.assert_allclose(got, gram_ref(a, a), rtol=1e-12, atol=1e-12)


def test_f32_dtype():
    a = rand((128, 8), 0, jnp.float32)
    b = rand((128, 4), 1, jnp.float32)
    got = gram_tt(a, b, block_n=64)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, gram_ref(a, b), rtol=1e-5, atol=1e-5)


def test_zero_padding_invariance():
    # zero rows and zero columns must not change the gram product block
    a = rand((96, 5), 2)
    b = rand((96, 7), 3)
    ref = gram_ref(a, b)
    a_pad = jnp.zeros((128, 9)).at[:96, :5].set(a)
    b_pad = jnp.zeros((128, 11)).at[:96, :7].set(b)
    got = gram_tt(a_pad, b_pad, block_n=64)
    np.testing.assert_allclose(got[:5, :7], ref, rtol=1e-12, atol=1e-12)
    assert float(jnp.abs(got[5:, :]).max()) == 0.0
    assert float(jnp.abs(got[:, 7:]).max()) == 0.0


def test_symmetry_of_self_gram():
    a = rand((256, 12), 4)
    g = gram_tt(a, a)
    np.testing.assert_allclose(g, g.T, rtol=0, atol=1e-12)
    # PSD: eigenvalues nonnegative
    w = np.linalg.eigvalsh(np.asarray(g))
    assert w.min() > -1e-10
