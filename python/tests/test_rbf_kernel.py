"""L1 correctness: the Pallas RBF kernel vs the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.rbf import rbf_cross
from compile.kernels.ref import rbf_ref

jax.config.update("jax_enable_x64", True)


def rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape))


@settings(max_examples=20, deadline=None)
@given(
    nx=st.integers(2, 100),
    ny=st.integers(2, 100),
    d=st.integers(1, 8),
    sigma=st.floats(0.3, 5.0),
    seed=st.integers(0, 2**31),
)
def test_matches_ref(nx, ny, d, sigma, seed):
    x = rand((nx, d), seed)
    y = rand((ny, d), seed + 1)
    got = rbf_cross(x, y, jnp.float64(sigma))
    np.testing.assert_allclose(got, rbf_ref(x, y, sigma), rtol=1e-12, atol=1e-12)


def test_blocked_grid_path():
    # sizes divisible by the block exercise the 2-D tiling
    x = rand((256, 4), 0)
    y = rand((384, 4), 1)
    got = rbf_cross(x, y, jnp.float64(1.5), block=128)
    np.testing.assert_allclose(got, rbf_ref(x, y, 1.5), rtol=1e-12, atol=1e-12)


def test_self_kernel_properties():
    x = rand((64, 3), 2)
    k = rbf_cross(x, x, jnp.float64(1.0))
    np.testing.assert_allclose(jnp.diagonal(k), jnp.ones(64), rtol=1e-12)
    np.testing.assert_allclose(k, k.T, atol=1e-12)
    assert float(k.min()) >= 0.0 and float(k.max()) <= 1.0 + 1e-12


def test_feature_zero_padding_invariance():
    # zero-padded feature dims leave RBF distances unchanged
    x = rand((40, 3), 3)
    y = rand((50, 3), 4)
    ref = rbf_ref(x, y, 2.0)
    xp = jnp.zeros((40, 8)).at[:, :3].set(x)
    yp = jnp.zeros((50, 8)).at[:, :3].set(y)
    got = rbf_cross(xp, yp, jnp.float64(2.0))
    np.testing.assert_allclose(got, ref, rtol=1e-12, atol=1e-12)
