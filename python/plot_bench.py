#!/usr/bin/env python3
"""Render the bench trajectory across commits from accumulated
``BENCH_<name>.json`` artifacts (the JSON twins the rust benches write,
uploaded per CI run — see ``rust/src/bench/mod.rs``).

Usage:

    python3 plot_bench.py RUN_DIR [RUN_DIR ...] [--metric COL] [--out DIR]

Each RUN_DIR is either one run's ``results/`` directory (its name labels
the commit/run), or a directory of such run directories (e.g. unpacked
CI artifacts, one subdirectory per commit, sorted by name).

Output:

* a plain-text trajectory table per bench on stdout — always (this is
  the table view; it needs nothing beyond the standard library);
* ``<out>/<bench>_trajectory.png`` line charts when matplotlib is
  importable (CI runners without it just keep the text view).

Chart conventions follow the repo's viz ground rules: one metric per
axis (never dual axes), small multiples per setting, at most 8 series
per panel (the rest are noted and live in the table view), a fixed
categorical color order, thin lines with visible markers, recessive
grid, and a legend whenever more than one series is shown.

Benches that carry a low-rank factorization axis (a ``lowrank`` column:
``icl`` / ``rff`` / ``-``) render one series per method with a shared
convention: ``rff`` series are dashed, everything else solid, so the
ICL-vs-RFF pairs of one setting read as one visual family.
"""

import argparse
import json
import os
import sys
from collections import OrderedDict

# Validated categorical palette (fixed slot order, light surface).
PALETTE = [
    "#2a78d6",  # blue
    "#eb6834",  # orange
    "#1baf7a",  # aqua
    "#eda100",  # yellow
    "#e87ba4",  # magenta
    "#008300",  # green
    "#4a3aa7",  # violet
    "#e34948",  # red
]
INK = "#1a1a19"
INK_MUTED = "#6b6a62"
GRID = "#e5e4dd"
MAX_SERIES = 8

# Default metric column per bench (others via --metric).
DEFAULT_METRIC = {
    "fig1_runtime": "cvlr_seconds",
    "fig2_4_synthetic": "f1_mean",
    "tab1_accuracy": "rel_error_pct",
    "tab1_sweep_m": "rel_error_pct",
}


def is_number(s):
    try:
        float(s)
        return True
    except (TypeError, ValueError):
        return False


def load_run(path):
    """All BENCH_*.json files directly inside `path` → {bench: (header, rows)}."""
    out = {}
    for fname in sorted(os.listdir(path)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        with open(os.path.join(path, fname)) as fh:
            doc = json.load(fh)
        out[doc["bench"]] = (doc["header"], doc["rows"])
    return out


def discover_runs(paths):
    """[(label, {bench: (header, rows)})] in label order."""
    runs = []
    for p in paths:
        p = p.rstrip("/")
        if not os.path.isdir(p):
            sys.exit(f"error: {p} is not a directory")
        direct = load_run(p)
        if direct:
            runs.append((os.path.basename(p) or p, direct))
            continue
        subs = sorted(
            d for d in os.listdir(p) if os.path.isdir(os.path.join(p, d))
        )
        found = False
        for d in subs:
            sub = load_run(os.path.join(p, d))
            if sub:
                runs.append((d, sub))
                found = True
        if not found:
            print(f"warning: no BENCH_*.json under {p}", file=sys.stderr)
    return runs


def series_of(header, rows, metric):
    """OrderedDict {(facet, series_label): value} for one run's table.

    The first non-numeric column facets the panels; the remaining
    non-metric columns label the series inside a panel.
    """
    if metric not in header:
        return None
    mi = header.index(metric)
    # facet column: first column that is non-numeric in some row
    facet_i = None
    for ci, _ in enumerate(header):
        if ci != mi and any(not is_number(r[ci]) for r in rows if len(r) > ci):
            facet_i = ci
            break
    out = OrderedDict()
    for r in rows:
        if len(r) <= mi or not is_number(r[mi]):
            continue
        facet = r[facet_i] if facet_i is not None else ""
        key_cells = [
            f"{header[ci]}={r[ci]}"
            for ci, _ in enumerate(header)
            if ci not in (mi, facet_i) and not header[ci].endswith(("_std",))
            and not is_metric_like(header[ci], metric)
        ]
        out[(facet, ", ".join(key_cells) or metric)] = float(r[mi])
    return out


def is_metric_like(col, metric):
    """Other measure columns are not identity: drop them from series keys."""
    measure_suffixes = (
        "_seconds", "_mean", "_std", "_pct", "_p50", "_p95", "seconds", "speedup", "_score",
        "_bytes", "_bytes_per_row",
    )
    return col != metric and (col.endswith(measure_suffixes) or col in ("rank_used",))


def text_view(bench, metric, labels, table):
    """Plain-text trajectory table: one row per series, one column per run."""
    keys = list(table.keys())
    name_w = max([len(f"{f} | {s}") for (f, s) in keys] + [len("series")])
    col_w = max([len(l) for l in labels] + [12])
    print(f"\n== {bench} — {metric} across {len(labels)} run(s) ==")
    head = "series".ljust(name_w) + "".join(l.rjust(col_w + 2) for l in labels)
    print(head)
    print("-" * len(head))
    for key in keys:
        facet, series = key
        cells = []
        for label in labels:
            v = table[key].get(label)
            cells.append(("-" if v is None else f"{v:.6g}").rjust(col_w + 2))
        print(f"{facet} | {series}".ljust(name_w) + "".join(cells))


def png_view(bench, metric, labels, table, out_dir, fname=None):
    try:
        import matplotlib

        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return None
    facets = list(OrderedDict.fromkeys(f for (f, _) in table))
    ncols = min(len(facets), 2)
    nrows = (len(facets) + ncols - 1) // ncols
    fig, axes = plt.subplots(
        nrows, ncols, figsize=(7.0 * ncols, 4.2 * nrows), squeeze=False
    )
    fig.patch.set_facecolor("white")
    x = list(range(len(labels)))
    for pi, facet in enumerate(facets):
        ax = axes[pi // ncols][pi % ncols]
        keys = [k for k in table if k[0] == facet]
        dropped = 0
        if len(keys) > MAX_SERIES:
            # trim whole lowrank families (series with the lowrank cell
            # stripped) ranked by their largest latest-run value, so a
            # dashed rff line never loses its color-matched icl twin;
            # the rest stay in the table view
            def base_of(key):
                return ", ".join(
                    c for c in key[1].split(", ") if not c.startswith("lowrank=")
                )

            groups = OrderedDict()
            for k in keys:
                groups.setdefault(base_of(k), []).append(k)
            ranked = sorted(
                groups.values(),
                key=lambda ks: -max(table[k].get(labels[-1]) or 0.0 for k in ks),
            )
            kept = []
            for ks in ranked:
                if len(kept) + len(ks) > MAX_SERIES:
                    break
                kept.extend(ks)
            if not kept:  # one family alone exceeds the cap: fall back
                keys.sort(key=lambda k: -(table[k].get(labels[-1]) or 0.0))
                kept = keys[:MAX_SERIES]
            dropped = len(keys) - len(kept)
            keys = kept
        # color by the series identity *without* the lowrank cell, so an
        # ICL/RFF pair shares a color and differs only by line style
        color_of = {}
        for key in keys:
            base = ", ".join(
                c for c in key[1].split(", ") if not c.startswith("lowrank=")
            )
            if base not in color_of:
                color_of[base] = PALETTE[len(color_of) % len(PALETTE)]
        for key in keys:
            ys = [table[key].get(l) for l in labels]
            base = ", ".join(
                c for c in key[1].split(", ") if not c.startswith("lowrank=")
            )
            # the per-factorization convention: rff dashed, others solid
            ax.plot(
                x,
                ys,
                color=color_of[base],
                linewidth=2,
                linestyle="--" if "lowrank=rff" in key[1] else "-",
                marker="o",
                markersize=6,
                label=key[1],
            )
        title = str(facet) if facet else bench
        if dropped:
            title += f"  (+{dropped} more series in the table view)"
        ax.set_title(title, color=INK, fontsize=11, loc="left")
        ax.set_ylabel(metric, color=INK_MUTED, fontsize=9)
        ax.set_xticks(x)
        ax.set_xticklabels(labels, rotation=30, ha="right", color=INK_MUTED, fontsize=8)
        ax.tick_params(colors=INK_MUTED)
        ax.grid(True, color=GRID, linewidth=0.8)
        ax.set_axisbelow(True)
        for spine in ("top", "right"):
            ax.spines[spine].set_visible(False)
        for spine in ("left", "bottom"):
            ax.spines[spine].set_color(GRID)
        if len(keys) > 1:
            ax.legend(fontsize=8, frameon=False, labelcolor=INK)
    for pi in range(len(facets), nrows * ncols):
        axes[pi // ncols][pi % ncols].set_visible(False)
    fig.suptitle(f"{bench} — {metric}", color=INK, fontsize=13, x=0.01, ha="left")
    fig.tight_layout(rect=(0, 0, 1, 0.96))
    path = os.path.join(out_dir, fname or f"{bench}_trajectory.png")
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return path


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("runs", nargs="+", help="run directory (or directory of run dirs)")
    ap.add_argument("--metric", help="metric column (default: per-bench)")
    ap.add_argument("--out", help="chart output directory (default: first run dir)")
    args = ap.parse_args()

    runs = discover_runs(args.runs)
    if not runs:
        sys.exit("error: no bench artifacts found")
    labels = [label for (label, _) in runs]
    out_dir = args.out or args.runs[0]

    benches = OrderedDict()
    for label, by_bench in runs:
        for bench in by_bench:
            benches.setdefault(bench, None)

    for bench in benches:
        metric = args.metric or DEFAULT_METRIC.get(bench)
        if not args.metric and metric is not None:
            # prefer the median over the mean when every run carries it:
            # at CI rep counts one cold-cache outlier moves the mean
            headers = [b[bench][0] for (_, b) in runs if bench in b]
            p50 = f"{metric}_p50"
            if headers and all(p50 in h for h in headers):
                metric = p50
        if metric is None:
            # fall back to the last numeric column of the first run
            header, rows = next(b[bench] for (_, b) in runs if bench in b)
            numeric = [c for ci, c in enumerate(header) if all(
                is_number(r[ci]) for r in rows if len(r) > ci)]
            if not numeric:
                continue
            metric = numeric[-1]
        # the primary metric, plus a memory panel when every run carries
        # the allocator columns — flat peak_bytes_per_row across n is the
        # O(n)-space evidence the bench records
        panels = [(metric, None)]
        headers = [b[bench][0] for (_, b) in runs if bench in b]
        mem_col = "peak_bytes_per_row"
        if metric != mem_col and headers and all(mem_col in h for h in headers):
            panels.append((mem_col, f"{bench}_memory.png"))
        for panel_metric, fname in panels:
            # {(facet, series): {label: value}}
            table = OrderedDict()
            for label, by_bench in runs:
                if bench not in by_bench:
                    continue
                header, rows = by_bench[bench]
                points = series_of(header, rows, panel_metric)
                if points is None:
                    continue
                for key, v in points.items():
                    table.setdefault(key, {})[label] = v
            if not table:
                continue
            text_view(bench, panel_metric, labels, table)
            png = png_view(bench, panel_metric, labels, table, out_dir, fname)
            if png:
                print(f"chart: {png}")
            else:
                print("(matplotlib unavailable — table view only)")


if __name__ == "__main__":
    main()
