//! The score service as a standalone component: batched
//! [`ScoreRequest`] streams routed through intra-batch dedup, the
//! single `ScoreCache` and a worker pool, with the batch-aware CV-LR
//! score underneath — on the AOT XLA artifacts when available, else the
//! native kernel. The serving-style view of the coordinator
//! (DESIGN.md §2, L3).
//!
//! Prints per-batch latency/throughput and the final service metrics.
//!
//! ```text
//! cargo run --release --example score_service [-- --n 1000 --workers 4]
//! ```

use std::sync::Arc;

use cvlr::coordinator::ScoreService;
use cvlr::data::synth::{generate, SynthConfig};
use cvlr::runtime::pjrt_kernel::PjrtCvLrKernel;
use cvlr::runtime::Runtime;
use cvlr::score::cvlr::CvLrScore;
use cvlr::score::folds::CvParams;
use cvlr::score::{ScoreBackend, ScoreRequest};
use cvlr::util::cli::Args;
use cvlr::util::timing::fmt_secs;
use cvlr::util::{Pcg64, Stopwatch};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 1000);
    let d = args.usize_or("vars", 10);
    let workers = args.usize_or("workers", 4);
    let batches = args.usize_or("batches", 5);
    let batch_size = args.usize_or("batch-size", 32);
    let artifacts = args.get_or("artifacts", "artifacts");

    let (ds, _) = generate(&SynthConfig {
        n,
        num_vars: d,
        density: 0.4,
        seed: 11,
        ..Default::default()
    });
    let ds = Arc::new(ds);

    // Backend: PJRT artifacts when available, else the native kernel.
    // CvLrScore implements ScoreBackend directly — one batch shares
    // factor construction and fold splits across all its candidates.
    let backend: Arc<dyn ScoreBackend> = match Runtime::load(&artifacts) {
        Ok(rt) => {
            println!("backend: PJRT artifacts ({} buckets)", rt.cvlr_buckets.len());
            Arc::new(CvLrScore::with_backend(
                ds.clone(),
                CvParams::default(),
                Default::default(),
                PjrtCvLrKernel::new(Arc::new(rt)),
            ))
        }
        Err(e) => {
            println!("backend: native (artifacts unavailable: {e})");
            Arc::new(CvLrScore::native(ds.clone()))
        }
    };
    let service = ScoreService::new(backend, workers);

    // Synthetic request stream: random (target, parent-set) queries with
    // realistic GES-like overlap (small parent sets, repeated queries).
    let mut rng = Pcg64::new(99);
    println!("\n{batches} batches x {batch_size} requests, {workers} workers:");
    for b in 0..batches {
        let reqs: Vec<ScoreRequest> = (0..batch_size)
            .map(|_| {
                let t = rng.below(d);
                let k = rng.below(3);
                let pa: Vec<usize> = (0..k)
                    .map(|_| {
                        let mut v = rng.below(d);
                        while v == t {
                            v = rng.below(d);
                        }
                        v
                    })
                    .collect();
                // ScoreRequest::new canonicalizes (sorts + dedups)
                ScoreRequest::new(t, &pa)
            })
            .collect();
        let sw = Stopwatch::start();
        let scores = service.score_batch(&reqs);
        let secs = sw.secs();
        let sum: f64 = scores.iter().sum();
        println!(
            "  batch {b}: {} req in {} ({:.1} req/s)   Σscores = {sum:.1}",
            reqs.len(),
            fmt_secs(secs),
            reqs.len() as f64 / secs.max(1e-12),
        );
    }

    let st = service.stats();
    assert!(st.consistent(), "stats identity must hold: {st:?}");
    println!("\nservice metrics:");
    println!("  requests     : {}", st.requests);
    println!("  cache hits   : {} ({:.0}%)", st.cache_hits, 100.0 * st.cache_hits as f64 / st.requests.max(1) as f64);
    println!("  evaluations  : {}", st.evaluations);
    println!("  dedup skips  : {}", st.dedup_skips);
    println!("  batches      : {} (max size {})", st.batches, st.max_batch);
    println!("  scoring time : {}", fmt_secs(st.eval_seconds));
    Ok(())
}
