//! Synthetic-data discovery across the paper's three data regimes
//! (§7.4): continuous, mixed continuous/discrete, and multi-dimensional
//! variables, over a density sweep — a compact version of Fig. 2.
//!
//! ```text
//! cargo run --release --example synthetic_discovery [-- --n 500 --reps 5]
//! ```

use std::sync::Arc;

use cvlr::coordinator::{discover, DiscoveryConfig, Method};
use cvlr::data::synth::{generate, DataKind, SynthConfig};
use cvlr::graph::{normalized_shd, skeleton_f1};
use cvlr::util::cli::Args;
use cvlr::util::csv::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 300);
    let reps = args.usize_or("reps", 3);

    let kinds = [
        (DataKind::Continuous, "continuous"),
        (DataKind::Mixed, "mixed"),
        (DataKind::MultiDim, "multi-dim"),
    ];
    let methods = [Method::CvLr, Method::Bic, Method::Sc];

    for (kind, kname) in kinds {
        let mut table =
            Table::new(&["density", "method", "F1 (mean)", "SHD (mean)", "time/run"]);
        for density in [0.2, 0.4, 0.6, 0.8] {
            for method in methods {
                // BIC assumes linear-Gaussian — the interesting comparison
                // of the paper is exactly how it degrades on this data.
                let mut f1s = vec![];
                let mut shds = vec![];
                let mut secs = 0.0;
                for rep in 0..reps {
                    let (ds, dag) = generate(&SynthConfig {
                        n,
                        num_vars: 7,
                        density,
                        kind,
                        seed: 1000 + rep as u64,
                    });
                    let out = discover(
                        Arc::new(ds),
                        &DiscoveryConfig { method, ..Default::default() },
                    )?;
                    f1s.push(skeleton_f1(&out.cpdag, &dag));
                    shds.push(normalized_shd(&out.cpdag, &dag));
                    secs += out.seconds;
                }
                let mf1 = f1s.iter().sum::<f64>() / reps as f64;
                let mshd = shds.iter().sum::<f64>() / reps as f64;
                table.row(&[
                    format!("{density:.1}"),
                    method.name().to_string(),
                    format!("{mf1:.3}"),
                    format!("{mshd:.3}"),
                    format!("{:.2}s", secs / reps as f64),
                ]);
            }
        }
        println!("\n== {kname} data (d=7, n={n}, {reps} reps) ==");
        println!("{}", table.render());
    }
    println!("(see `cargo bench --bench fig2_4_synthetic` for the full Fig. 2-4 sweep)");
    Ok(())
}
