//! End-to-end driver on the real-world benchmark networks (paper §7.5):
//! learn SACHS (11 vars / 17 edges) and CHILD (20 vars / 25 edges) from
//! forward-sampled data, with both CV-LR (through the full three-layer
//! PJRT hot path when artifacts are available) and the exact CV score on
//! a subsample, reporting the paper's headline metric — the CV/CV-LR
//! runtime ratio at matched accuracy.
//!
//! ```text
//! cargo run --release --example realworld_networks [-- --n 1000 --cv-n 300]
//! ```

use std::sync::Arc;

use cvlr::coordinator::{discover, DiscoveryConfig, EngineKind, Method};
use cvlr::data::networks;
use cvlr::graph::{normalized_shd, skeleton_f1};
use cvlr::util::cli::Args;
use cvlr::util::csv::Table;
use cvlr::util::timing::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n = args.usize_or("n", 1000);
    // exact CV is O(n³) per score — cap its sample size separately so the
    // example stays interactive (pass --cv-n 0 to skip CV entirely).
    let cv_n = args.usize_or("cv-n", 300);
    let artifacts = args.get_or("artifacts", "artifacts");
    let pjrt_ok = cvlr::runtime::Runtime::load(&artifacts).is_ok();

    for net in [networks::sachs(), networks::child()] {
        println!("\n=== {} ({} vars, {} edges, n={n}) ===", net.name, net.dag.parent_list().len(), net.dag.num_edges());
        let ds = Arc::new(networks::forward_sample(&net, n, 5));
        let mut table = Table::new(&["method", "engine", "n", "F1", "SHD", "time"]);

        // CV-LR through the native backend
        let out = discover(ds.clone(), &DiscoveryConfig::default())?;
        let t_cvlr = out.seconds;
        table.row(&[
            "CV-LR".into(),
            "native".into(),
            n.to_string(),
            format!("{:.3}", skeleton_f1(&out.cpdag, &net.dag)),
            format!("{:.3}", normalized_shd(&out.cpdag, &net.dag)),
            fmt_secs(out.seconds),
        ]);

        // CV-LR through the AOT XLA artifacts (the three-layer hot path)
        if pjrt_ok {
            let out = discover(
                ds.clone(),
                &DiscoveryConfig {
                    engine: EngineKind::Pjrt,
                    artifacts_dir: artifacts.clone(),
                    ..Default::default()
                },
            )?;
            table.row(&[
                "CV-LR".into(),
                "pjrt".into(),
                n.to_string(),
                format!("{:.3}", skeleton_f1(&out.cpdag, &net.dag)),
                format!("{:.3}", normalized_shd(&out.cpdag, &net.dag)),
                fmt_secs(out.seconds),
            ]);
        }

        // BDeu baseline (the discrete-data specialist)
        let out = discover(
            ds.clone(),
            &DiscoveryConfig { method: Method::Bdeu, ..Default::default() },
        )?;
        table.row(&[
            "BDeu".into(),
            "-".into(),
            n.to_string(),
            format!("{:.3}", skeleton_f1(&out.cpdag, &net.dag)),
            format!("{:.3}", normalized_shd(&out.cpdag, &net.dag)),
            fmt_secs(out.seconds),
        ]);

        // exact CV on a subsample — the O(n³) baseline the paper
        // accelerates; its runtime ratio vs CV-LR is the headline claim.
        if cv_n >= 40 {
            let ds_small = Arc::new(networks::forward_sample(&net, cv_n, 5));
            let out_cv = discover(
                ds_small.clone(),
                &DiscoveryConfig { method: Method::Cv, ..Default::default() },
            )?;
            table.row(&[
                "CV".into(),
                "native".into(),
                cv_n.to_string(),
                format!("{:.3}", skeleton_f1(&out_cv.cpdag, &net.dag)),
                format!("{:.3}", normalized_shd(&out_cv.cpdag, &net.dag)),
                fmt_secs(out_cv.seconds),
            ]);
            let out_lr = discover(ds_small, &DiscoveryConfig::default())?;
            println!(
                "CV/CV-LR runtime ratio at n={cv_n}: {:.0}x (paper: 600-1000x at n=2000)",
                out_cv.seconds / out_lr.seconds.max(1e-9)
            );
        }
        println!("{}", table.render());
        let _ = t_cvlr;
    }
    Ok(())
}
