//! Quickstart: discover the causal structure of a small nonlinear
//! system with the CV-LR score in a few lines, through the
//! `Discovery` builder façade.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use cvlr::coordinator::{Discovery, EngineKind};
use cvlr::data::Dataset;
use cvlr::graph::{normalized_shd, skeleton_f1, Dag};
use cvlr::linalg::Mat;
use cvlr::util::Pcg64;

fn main() -> anyhow::Result<()> {
    // 1. Some data with a known nonlinear causal structure:
    //    X0 → X1 → X2,  X0 → X3,  X4 independent.
    let n = 500;
    let mut rng = Pcg64::new(42);
    let mut data = Mat::zeros(n, 5);
    for r in 0..n {
        let x0 = rng.normal();
        let x1 = (1.5 * x0).sin() + 0.3 * rng.normal();
        let x2 = (x1 * x1) * 0.8 + 0.3 * rng.normal();
        let x3 = (2.0 * x0).tanh() + 0.3 * rng.normal();
        let x4 = rng.normal();
        data[(r, 0)] = x0;
        data[(r, 1)] = x1;
        data[(r, 2)] = x2;
        data[(r, 3)] = x3;
        data[(r, 4)] = x4;
    }
    let ds = Arc::new(Dataset::from_columns(data, &[false; 5]));

    // 2. Run batched GES with the CV-LR score (the paper's method).
    //    The builder picks methods by registry name; `.engine(
    //    EngineKind::Pjrt)` switches the CV-LR fold kernels to the AOT
    //    XLA artifacts, `.workers(w)` sizes the score-service pool.
    let out = Discovery::builder(ds)
        .method("cv-lr")
        .engine(EngineKind::Native)
        .workers(2)
        .run()?;

    // 3. Inspect the learned equivalence class.
    println!("learned CPDAG in {:.2}s:", out.seconds);
    for i in 0..5 {
        for j in 0..5 {
            if out.cpdag.directed(i, j) {
                println!("  X{i} → X{j}");
            } else if i < j && out.cpdag.undirected(i, j) {
                println!("  X{i} — X{j}");
            }
        }
    }

    // 4. Compare against the ground truth.
    let truth = Dag::from_edges(5, &[(0, 1), (1, 2), (0, 3)]);
    println!("skeleton F1    : {:.3}", skeleton_f1(&out.cpdag, &truth));
    println!("normalized SHD : {:.3}", normalized_shd(&out.cpdag, &truth));
    let stats = out.score_stats.expect("score-based method");
    println!(
        "score service  : {} requests in {} batches (max {}), {} unique \
         evaluations ({:.0}% cache hits)",
        stats.requests,
        stats.batches,
        stats.max_batch,
        stats.evaluations,
        100.0 * stats.cache_hits as f64 / stats.requests.max(1) as f64
    );
    Ok(())
}
