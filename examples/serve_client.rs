//! End-to-end client of the discovery server: starts a server on an
//! ephemeral port in-process, registers a dataset, submits a job, polls
//! it to completion, prints the learned edges, and shuts the server
//! down gracefully — the same HTTP/JSON protocol curl speaks from the
//! shell (see the `server` module docs for the endpoint table).
//!
//! ```text
//! cargo run --release --example serve_client
//! ```

use std::time::{Duration, Instant};

use cvlr::server::http::request;
use cvlr::server::json::Json;
use cvlr::server::{Server, ServerConfig};

fn main() -> anyhow::Result<()> {
    let server = Server::start(ServerConfig { port: 0, builtin_n: 200, ..Default::default() })?;
    let addr = server.addr();
    println!("server on http://{addr}");

    // 1. register a parameterized built-in dataset
    //    (uploads work the same way with {"name", "csv"} instead)
    let (st, resp) = request(
        addr,
        "POST",
        "/v1/datasets",
        Some(&Json::obj(vec![
            ("name", Json::str("demo")),
            ("builtin", Json::str("synth")),
            ("n", Json::Num(300.0)),
            ("seed", Json::Num(1.0)),
        ])),
    )?;
    anyhow::ensure!(st == 201, "dataset registration failed: {resp:?}");
    println!(
        "registered `demo`: n={} d={}",
        resp.get("n").and_then(Json::as_u64).unwrap_or(0),
        resp.get("d").and_then(Json::as_u64).unwrap_or(0),
    );

    // 2. submit a discovery job
    let (st, resp) = request(
        addr,
        "POST",
        "/v1/jobs",
        Some(&Json::obj(vec![("dataset", Json::str("demo")), ("method", Json::str("cv-lr"))])),
    )?;
    anyhow::ensure!(st == 202, "submit failed: {resp:?}");
    let id = resp.get("id").and_then(Json::as_u64).expect("job id");
    println!("submitted job {id}");

    // 3. poll state + progress until terminal
    let t0 = Instant::now();
    let job = loop {
        let (_, job) = request(addr, "GET", &format!("/v1/jobs/{id}"), None)?;
        let state = job.get("state").and_then(Json::as_str).unwrap_or("?").to_string();
        let p = job.get("progress");
        println!(
            "  {state}: {} sweeps, {} candidates, hit rate {:.0}%",
            p.and_then(|p| p.get("sweeps")).and_then(Json::as_u64).unwrap_or(0),
            p.and_then(|p| p.get("candidates")).and_then(Json::as_u64).unwrap_or(0),
            p.and_then(|p| p.get("cache_hit_rate")).and_then(Json::as_f64).unwrap_or(0.0) * 100.0,
        );
        if state == "done" || state == "failed" || state == "cancelled" {
            break job;
        }
        anyhow::ensure!(t0.elapsed() < Duration::from_secs(600), "job timed out");
        std::thread::sleep(Duration::from_millis(200));
    };

    // 4. read the result: edge list, SHD-ready adjacency, cache stats
    if let Some(result) = job.get("result") {
        println!(
            "learned CPDAG in {:.2}s ({} edges):",
            result.get("seconds").and_then(Json::as_f64).unwrap_or(0.0),
            result.get("num_edges").and_then(Json::as_u64).unwrap_or(0),
        );
        for e in result.get("edges").and_then(Json::as_arr).unwrap_or(&[]) {
            let from = e.get("from").and_then(Json::as_u64).unwrap_or(0);
            let to = e.get("to").and_then(Json::as_u64).unwrap_or(0);
            let arrow =
                if e.get("directed").and_then(Json::as_bool) == Some(true) { "→" } else { "—" };
            println!("  X{from} {arrow} X{to}");
        }
        if let Some(stats) = result.get("stats") {
            println!("service stats: {}", stats.encode());
        }
    } else if let Some(err) = job.get("error") {
        println!("job failed: {err:?}");
    }

    // 5. server-wide stats, then graceful shutdown over the wire
    let (_, stats) = request(addr, "GET", "/v1/stats", None)?;
    println!("server stats: {}", stats.encode());
    let (st, _) = request(addr, "POST", "/v1/shutdown", Some(&Json::obj(vec![])))?;
    anyhow::ensure!(st == 200, "shutdown failed");
    server.wait();
    println!("server drained and stopped");
    Ok(())
}
